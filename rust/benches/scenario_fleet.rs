//! Bench: fleet-scale scenario throughput + the parallel multi-seed
//! executor. Runs a 4-node, 36-job Poisson-arrival scenario (with a node
//! drain and a random kill) under ARC-V and the VPA simulator, times an
//! 8-seed ARC-V grid serially vs. in parallel (verifying the fan-out is
//! bit-identical to the serial reference), and then runs the fleet-SCALE
//! ladder: 1k/10k/100k-pod backlogs (plus the 10⁶-pod rung, sharded
//! kernel only) with one swap-thrashing leaker, under {lockstep, serial
//! event kernel, sharded kernel}, emitting `bench_out/BENCH_scale.json`
//! (ticks/s + wall-clock per cell, the informer's per-wake delta cost,
//! and the interned-calibration-table RSS proxy). A final thrash rung
//! drives parallel stepping regions directly: a fleet where every node
//! hosts 25 % proof-defeating pods, over a 4-way-sharded event store,
//! timed per region thread count with an FNV fingerprint of the event
//! log and the per-shard append spread per run (the `thrash` block in
//! `BENCH_scale.json`).
//!
//!   cargo bench --bench scenario_fleet
//!
//! Env knobs:
//!   SCALE_MAX_JOBS — largest ladder rung to run (default 100_000; set
//!                    1_000_000 to include the million-pod rung)
//!   SCALE_MIN_JOBS — smallest rung to run (default 0)
//!   SCALE_ONLY=1   — skip the fleet-scenario / grid sections and run
//!                    just the ladder (the CI million-rung smoke job)
//!
//! Emits a machine-readable `BENCH {json}` block at the end. Exits
//! non-zero if any pod is stuck Pending at drain, the parallel grid
//! diverges from the serial one, any kernel flavor diverges from
//! lockstep on the scale ladder, the sharded kernel is slower than the
//! serial event kernel there (the fleet-scale regression gate), the
//! delta informer relists after its initial LIST, parallel stepping
//! regions run slower than serial regions on the thrash rung, or the
//! event-log hash differs across region thread counts there. (Per-wake
//! informer rebuild counts are *reported* in BENCH_scale.json; the
//! controlled delta-vs-relist cost gate lives in perf_sim's
//! BENCH_informer.)

use arcv::harness::SwapKind;
use arcv::policy::arcv::ArcvParams;
use arcv::scenario::{
    outcome_json, outcome_line, run_grid, run_scenario, run_scenario_mode, summarize,
    summary_line, Arrivals, Fault, LeakProcess, ScenarioOutcome, ScenarioPolicy, ScenarioSpec,
    WorkloadMix,
};
use arcv::simkube::{
    AdvanceOpts, Cluster, ClusterConfig, Event, InformerStats, KernelMode, MemoryProcess, Node,
    ResourceSpec, SubscriptionSet, SwapDevice,
};
use arcv::util::json::{arr, num, obj, s, Json};
use arcv::workloads::{intern_stats, live_tables, AppId};
use std::time::Instant;

fn fleet_spec() -> ScenarioSpec {
    // Heterogeneous pools: two paper-spec 256 GB workers + two small 96 GB
    // workers. 36 jobs arrive Poisson at 4/min (~9 min submission window);
    // mid-run one small node drains and one random pod is killed.
    ScenarioSpec::new("fleet-poisson")
        .pool("big", 2, 256.0, SwapKind::Hdd(128.0))
        .pool("small", 2, 96.0, SwapKind::Ssd(32.0))
        .arrivals(Arrivals::Poisson { rate_per_min: 4.0 })
        .jobs(36)
        .mix(WorkloadMix::uniform(&[
            AppId::Amr,
            AppId::Bfs,
            AppId::Cm1,
            AppId::Kripke,
            AppId::Lulesh,
            AppId::Minife,
            AppId::Sputnipic,
        ]))
        .fault(Fault::KillRandomPod { at: 300 })
        .fault(Fault::DrainNode { at: 600, node: 3 })
        .max_ticks(120_000)
}

/// One rung of the fleet-scale ladder: `jobs` flat-start jobs from the
/// three smooth Growth apps (so coast windows stay long — and so the
/// calibration-table interner collapses the fleet to THREE table sets),
/// one node per ~10 jobs, plus a mid-life leaker that outgrows its 120 %
/// limit at t ≈ 85 and thrashes in swap for the rest of the run — the
/// mixed cluster that used to collapse the whole fleet to 1 s stepping.
fn scale_spec(jobs: usize) -> ScenarioSpec {
    let nodes = (jobs / 10).max(1);
    let max_ticks = if jobs >= 1_000_000 {
        300 // the smoke horizon: past the leaker's swap collapse at t≈85
    } else if jobs >= 100_000 {
        1_000
    } else {
        2_000
    };
    ScenarioSpec::new(&format!("scale-{jobs}"))
        .pool("w", nodes, 64.0, SwapKind::Hdd(32.0))
        .mix(WorkloadMix::uniform(&[AppId::Amr, AppId::Cm1, AppId::Sputnipic]))
        .arrivals(Arrivals::Backlog)
        .jobs(jobs)
        .fault(Fault::LeakyPod {
            at: 60,
            base_gb: 2.0,
            leak_gb_per_sec: 0.02,
            lifetime_secs: 3_000.0,
        })
        // rings are preallocated per sampled pod: keep them shallow at
        // fleet scale (nothing scrapes them under the fixed policy)
        .metrics_history(64)
        .max_ticks(max_ticks)
}

/// One `(spec, mode)` ladder cell. The cluster is dropped before
/// returning so multi-hundred-thousand-pod runs never coexist in memory;
/// `keep_events` controls whether the event log survives for the
/// divergence comparison (off at the million rung, where only one kernel
/// flavor runs).
struct Cell {
    secs: f64,
    outcome: ScenarioOutcome,
    events: Vec<Event>,
    ticks: u64,
    informer: InformerStats,
    /// Distinct calibration-table sets alive while the fleet existed —
    /// the RSS proxy (vs `jobs` pods).
    live_tables: usize,
    /// Controller decision wakes and the wall time spent inside them —
    /// the decision-plane cost the ladder records per kernel mode.
    decide_passes: u64,
    decide_secs: f64,
}

fn scale_cell(spec: &ScenarioSpec, mode: KernelMode, keep_events: bool) -> Cell {
    let t0 = Instant::now();
    let run = run_scenario_mode(spec, ScenarioPolicy::Fixed, 42, mode);
    let secs = t0.elapsed().as_secs_f64();
    let live = live_tables(); // counted while the fleet's models are alive
    Cell {
        secs,
        outcome: run.outcome,
        events: if keep_events { run.cluster.events.into_snapshot() } else { Vec::new() },
        ticks: run.stats.sim_ticks,
        informer: run.informer,
        live_tables: live,
        decide_passes: run.coast.decide_passes,
        decide_secs: run.coast.decide_nanos as f64 / 1e9,
    }
}

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// Thrash-rung shape: node capacity is `2 GB × pods`, so best-fit packs
/// exactly this many 2 GB requests per node, in pod-id order.
const THRASH_PODS_PER_NODE: usize = 100;
const THRASH_NODES: usize = 100;
const THRASH_TICKS: u64 = 600;
/// Event-log watch shards on the thrash rung (contiguous node chunks).
const THRASH_EVENT_SHARDS: usize = 4;

/// A flat memory process: constant usage, effectively immortal (nothing
/// on the thrash rung may complete — completions would interrupt regions
/// and muddy the wall-clock comparison).
fn flat_process(usage_gb: f64) -> Box<dyn MemoryProcess> {
    Box::new(LeakProcess {
        base_gb: usage_gb,
        leak_gb_per_sec: 0.0,
        lifetime_secs: 1.0e7,
    })
}

/// The thrash-rung fleet: every node hosts 25 % proof-defeating pods
/// (flat usage parked 25 % over the limit — permanent swap residency and
/// I/O debt fail the per-pod quiescence proof every tick) alongside 75 %
/// calm under-limit pods. Every node is hot, so `advance_to` runs one
/// stepping region after another — the many-simultaneous-regions shape
/// the shard-local event buffers parallelize. No metrics subscriptions
/// are installed, so regions always run to their proof ceiling, never to
/// a scrape tick.
fn thrash_cluster() -> Cluster {
    let nodes: Vec<Node> = (0..THRASH_NODES)
        .map(|i| {
            Node::new(
                &format!("thrash{i}"),
                2.0 * THRASH_PODS_PER_NODE as f64,
                SwapDevice::hdd(32.0),
            )
        })
        .collect();
    // shallow metric rings: the rung never scrapes, and 10⁴ pods ×
    // the default 8192-deep rings would be pure allocation noise
    let mut c = Cluster::new(
        nodes,
        ClusterConfig {
            metrics_history: 64,
            ..ClusterConfig::default()
        },
    );
    // the event store shards 4 ways (contiguous 25-node chunks): region
    // workers append straight into their nodes' shard, and the rung
    // records the per-shard append spread alongside the merge time
    c.set_event_shards((0..THRASH_NODES).map(|n| n * THRASH_EVENT_SHARDS / THRASH_NODES).collect());
    c.install_subscriptions(SubscriptionSet::new());
    for i in 0..THRASH_NODES * THRASH_PODS_PER_NODE {
        let usage = if i % 4 == 0 { 2.5 } else { 1.0 };
        // create_pod self-places while capacity lasts; requests exactly
        // fill every node, so nothing may be left Pending
        c.create_pod(&format!("p{i}"), ResourceSpec::memory_exact(2.0), flat_process(usage));
    }
    let pending = c.pods.iter().filter(|p| p.node.is_none()).count();
    assert_eq!(pending, 0, "thrash fleet must place fully");
    c
}

/// FNV-1a over the debug rendering of every event — the event-log
/// fingerprint BENCH_scale.json records per region thread count.
fn event_log_hash(events: &[Event]) -> u64 {
    use std::fmt::Write as _;
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut line = String::new();
    for e in events {
        line.clear();
        let _ = write!(line, "{e:?}");
        for &b in line.as_bytes() {
            h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
        }
        h = (h ^ 0x0a).wrapping_mul(0x100_0000_01b3);
    }
    h
}

fn main() {
    let scale_only = std::env::var("SCALE_ONLY").map(|v| v == "1").unwrap_or(false);
    let scale_max = env_usize("SCALE_MAX_JOBS", 100_000);
    let scale_min = env_usize("SCALE_MIN_JOBS", 0);

    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    let mut stuck_total = 0usize;
    let mut unfinished_total = 0usize;
    let mut singles: Vec<ScenarioOutcome> = Vec::new();
    let mut kernel_json = Json::Null;
    let mut kernel_identical = true;
    let mut kernel_speedup = f64::INFINITY;
    let mut grid_identical = true;
    let mut grid_speedup = f64::INFINITY;
    let mut grid_required = 0.0f64;
    let mut grid_serial_s = 0.0f64;
    let mut grid_parallel_s = 0.0f64;

    if !scale_only {
        let spec = fleet_spec();
        let policies = [
            ScenarioPolicy::Arcv(ArcvParams::default()),
            ScenarioPolicy::VpaSim,
        ];

        println!("=== single-seed fleet scenario: ARC-V vs VPA-sim ===\n");
        for policy in policies {
            let t0 = Instant::now();
            let run = run_scenario(&spec, policy, 42);
            let secs = t0.elapsed().as_secs_f64();
            println!("{}   ({secs:.2}s wall)", outcome_line(&run.outcome));
            stuck_total += run.outcome.stuck_pending;
            // a truncated or livelocked run must fail loudly, not slip past
            // a stuck-Pending-only gate
            unfinished_total += run.outcome.unfinished + run.outcome.jobs_dropped;
            singles.push(run.outcome);
        }
        let arcv = &singles[0];
        let vpa = &singles[1];
        if arcv.used_gb_h > 0.0 && vpa.used_gb_h > 0.0 {
            println!(
                "\nallocated/used: arcv {:.2}x  vpa-sim {:.2}x  (reclaimed capacity is what \
                 admits more queued work per node)",
                arcv.allocated_gb_h / arcv.used_gb_h,
                vpa.allocated_gb_h / vpa.used_gb_h,
            );
        }

        println!("\n=== kernel: event-driven clock vs 1 s-stepping on the fleet scenario ===\n");
        let arcv_policy = ScenarioPolicy::Arcv(ArcvParams::default());
        let t0 = Instant::now();
        let lockstep_run = run_scenario_mode(&spec, arcv_policy, 42, KernelMode::Lockstep);
        let kernel_lockstep_secs = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let event_run = run_scenario_mode(&spec, arcv_policy, 42, KernelMode::EventDriven);
        let kernel_event_secs = t0.elapsed().as_secs_f64();
        kernel_identical = lockstep_run.outcome == event_run.outcome
            && lockstep_run.cluster.events.snapshot() == event_run.cluster.events.snapshot();
        kernel_speedup = kernel_lockstep_secs / kernel_event_secs.max(1e-9);
        let ticks = event_run.stats.sim_ticks;
        println!(
            "lockstep {kernel_lockstep_secs:.3}s  event {kernel_event_secs:.3}s over {ticks} \
             sim-seconds -> {kernel_speedup:.2}x speedup, {} kernel events, results {}",
            event_run.stats.events,
            if kernel_identical { "bit-identical" } else { "DIVERGED" },
        );
        kernel_json = obj(vec![
            ("bench", s("scenario_fleet/kernel")),
            ("sim_ticks", num(ticks as f64)),
            ("kernel_events", num(event_run.stats.events as f64)),
            ("ctl_wakes", num(event_run.stats.ctl_wakes as f64)),
            ("lockstep_secs", num(kernel_lockstep_secs)),
            ("event_secs", num(kernel_event_secs)),
            ("speedup", num(kernel_speedup)),
            ("events_per_sec", num(event_run.stats.events as f64 / kernel_event_secs.max(1e-9))),
            ("ticks_per_sec_event", num(ticks as f64 / kernel_event_secs.max(1e-9))),
            ("identical", Json::Bool(kernel_identical)),
        ]);
        std::fs::create_dir_all("bench_out").ok();
        std::fs::write("bench_out/BENCH_kernel_fleet.json", kernel_json.to_string_pretty())
            .expect("write bench_out/BENCH_kernel_fleet.json");

        println!("\n=== parallel multi-seed executor: 8 ARC-V seeds, serial vs parallel ===\n");
        let seeds: Vec<u64> = (1..=8).collect();
        let grid_policies = [ScenarioPolicy::Arcv(ArcvParams::default())];
        let specs = [fleet_spec()];

        let t0 = Instant::now();
        let serial = run_grid(&specs, &grid_policies, &seeds, 1);
        let serial_s = t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        let parallel = run_grid(&specs, &grid_policies, &seeds, 0);
        let parallel_s = t0.elapsed().as_secs_f64();

        grid_identical = serial == parallel;
        grid_speedup = serial_s / parallel_s.max(1e-9);
        grid_serial_s = serial_s;
        grid_parallel_s = parallel_s;
        // parallelism-aware gate: a fully-serialized executor regression
        // shows up as ~1.0x on any machine, so require scaling
        // proportional to the cores actually available (on >=8 cores this
        // demands the >=3x of the acceptance criterion; on a 2-core box
        // it still catches serialization)
        let eff_threads = threads.min(seeds.len()) as f64;
        grid_required = 1.0 + 0.3 * (eff_threads - 1.0);
        println!("serial:   {serial_s:.2}s for {} runs", serial.len());
        println!(
            "parallel: {parallel_s:.2}s on {threads} threads  -> {grid_speedup:.2}x speedup \
             (required >= {grid_required:.2}x)"
        );
        println!(
            "parallel results {} the serial reference",
            if grid_identical { "bit-identical to" } else { "DIVERGE FROM" }
        );
        for line in summarize(&serial).iter().map(summary_line) {
            println!("{line}");
        }
        stuck_total += serial.iter().map(|o| o.stuck_pending).sum::<usize>();
        unfinished_total += serial.iter().map(|o| o.unfinished + o.jobs_dropped).sum::<usize>();
    }

    println!("\n=== fleet scale: sharded vs serial event kernel vs lockstep ===\n");
    let mut scale_rows = Vec::new();
    let mut scale_diverged = false;
    let mut scale_sharded_slow = false;
    let mut informer_relisted = false;
    // 0.0 = "rung not run" (SCALE_MAX_JOBS trimmed it) — keeps the json valid
    let mut speedup_10k = 0.0_f64;
    for jobs in [1_000usize, 10_000, 100_000, 1_000_000] {
        if jobs > scale_max || jobs < scale_min {
            println!(
                "  (skipping {jobs}-pod rung: SCALE_MIN_JOBS={scale_min} \
                 SCALE_MAX_JOBS={scale_max})"
            );
            continue;
        }
        let sspec = scale_spec(jobs);
        let million = jobs >= 1_000_000;
        // one run in memory at a time: each cell drops its cluster.
        // The million rung runs the sharded kernel only — lockstep at 10⁶
        // pods × 300 ticks is 3·10⁸ kubelet ticks of pure reference; the
        // ≤100k rungs pin all three flavors bit-for-bit, and the
        // kernel-equivalence suite covers the kernels at test scale.
        let sharded = scale_cell(&sspec, KernelMode::Sharded { threads: 0 }, !million);
        let (lock, serial) = if million {
            (None, None)
        } else {
            let lock = scale_cell(&sspec, KernelMode::Lockstep, true);
            let serial = scale_cell(&sspec, KernelMode::EventDriven, true);
            (Some(lock), Some(serial))
        };

        // informer gate, every rung: no relist after the initial LIST.
        // (Per-wake rebuild counts are REPORTED below — an individual wake
        // may legitimately carry a fleet-sized delta when completions
        // batch onto one tick; the controlled per-wake delta-vs-relist
        // gate lives in perf_sim's BENCH_informer.)
        if sharded.informer.relists > 1 {
            informer_relisted = true;
        }
        let rebuilds_per_sync =
            sharded.informer.views_rebuilt as f64 / sharded.informer.syncs.max(1) as f64;

        let identical = match (&lock, &serial) {
            (Some(l), Some(sv)) => {
                l.outcome == sv.outcome
                    && l.outcome == sharded.outcome
                    && l.events == sv.events
                    && l.events == sharded.events
            }
            _ => true, // million rung: single flavor, nothing to diverge
        };
        if !identical {
            scale_diverged = true;
        }
        let lock_secs = lock.as_ref().map(|c| c.secs).unwrap_or(0.0);
        let serial_secs = serial.as_ref().map(|c| c.secs).unwrap_or(0.0);
        let shard_secs = sharded.secs;
        let ticks = sharded.ticks;
        let vs_serial = serial_secs / shard_secs.max(1e-9);
        let vs_lockstep = lock_secs / shard_secs.max(1e-9);
        if jobs == 10_000 {
            speedup_10k = vs_serial;
        }
        // the regression gate: sharded must never be slower than the
        // PR 3 serial event kernel (5 % tolerance for runner noise)
        if serial.is_some() && shard_secs > serial_secs * 1.05 {
            scale_sharded_slow = true;
        }
        if million {
            println!(
                "  {jobs:>7} pods over {ticks} sim-s: sharded {shard_secs:>7.2}s \
                 ({} tables interned for {jobs} pods, {rebuilds_per_sync:.0} view \
                 rebuilds/wake, {} ctl syncs)",
                sharded.live_tables, sharded.informer.syncs,
            );
        } else {
            println!(
                "  {jobs:>7} pods over {ticks} sim-s: lockstep {lock_secs:>7.2}s  serial-event \
                 {serial_secs:>7.2}s  sharded {shard_secs:>7.2}s  -> {vs_serial:.2}x vs serial, \
                 {vs_lockstep:.2}x vs lockstep, {} ({} tables, {rebuilds_per_sync:.0} \
                 rebuilds/wake)",
                if identical { "bit-identical" } else { "DIVERGED" },
                sharded.live_tables,
            );
        }
        scale_rows.push(obj(vec![
            ("jobs", num(jobs as f64)),
            ("nodes", num(sspec.node_count() as f64)),
            ("sim_ticks", num(ticks as f64)),
            ("lockstep_secs", num(lock_secs)),
            ("serial_event_secs", num(serial_secs)),
            ("sharded_secs", num(shard_secs)),
            ("sharded_vs_serial_speedup", num(vs_serial)),
            ("sharded_vs_lockstep_speedup", num(vs_lockstep)),
            (
                "ticks_per_sec_lockstep",
                num(if lock_secs > 0.0 { ticks as f64 / lock_secs } else { 0.0 }),
            ),
            (
                "ticks_per_sec_serial_event",
                num(if serial_secs > 0.0 { ticks as f64 / serial_secs } else { 0.0 }),
            ),
            ("ticks_per_sec_sharded", num(ticks as f64 / shard_secs.max(1e-9))),
            // the RSS proxy: distinct interned table sets vs fleet size
            ("live_model_tables", num(sharded.live_tables as f64)),
            // per-wake informer cost: rebuilds track the delta, not jobs
            ("informer_syncs", num(sharded.informer.syncs as f64)),
            ("informer_relists", num(sharded.informer.relists as f64)),
            ("informer_views_rebuilt", num(sharded.informer.views_rebuilt as f64)),
            ("informer_rebuilds_per_sync", num(rebuilds_per_sync)),
            // decision-plane cost per kernel mode: controller decision
            // wakes and the wall time spent inside them (0.0 = mode not
            // run on this rung)
            ("decide_passes", num(sharded.decide_passes as f64)),
            ("decide_secs_sharded", num(sharded.decide_secs)),
            (
                "decide_secs_lockstep",
                num(lock.as_ref().map(|c| c.decide_secs).unwrap_or(0.0)),
            ),
            (
                "decide_secs_serial_event",
                num(serial.as_ref().map(|c| c.decide_secs).unwrap_or(0.0)),
            ),
            // whether cross-kernel equivalence actually ran on this rung:
            // the million rung runs one flavor only, so `identical` would
            // be an unearned claim there — record null instead
            ("kernels_compared", Json::Bool(!million)),
            (
                "identical",
                if million { Json::Null } else { Json::Bool(identical) },
            ),
        ]));
    }
    println!("\n=== thrash rung: parallel stepping regions vs serial regions ===\n");
    // The rung every other section can't produce: ALL nodes hot at once,
    // 25 % of the fleet proof-defeating, zero coasts. Shards = 1 is the
    // serial-region baseline (same region machinery, one worker); the
    // lockstep run is the ground-truth event-log fingerprint.
    let mut thrash_rows = Vec::new();
    let mut thrash_parallel_slow = false;
    let mut thrash_hash_mismatch = false;
    let mut thrash_no_regions = false;
    let mut thrash_not_parallel = false;

    let mut reference = thrash_cluster();
    let t0 = Instant::now();
    reference.run_until(THRASH_TICKS, |_| false);
    let thrash_lockstep_secs = t0.elapsed().as_secs_f64();
    let thrash_ref_hash = event_log_hash(&reference.events.snapshot());
    drop(reference);

    let thread_counts: Vec<usize> =
        [1usize, 2, 4, 8].into_iter().filter(|&t| t <= threads).collect();
    let mut thrash_serial_secs = 0.0_f64;
    for &count in &thread_counts {
        let mut c = thrash_cluster();
        let opts = AdvanceOpts {
            event_driven: true,
            sample_metrics: true,
            shards: count,
        };
        let t0 = Instant::now();
        while c.now < THRASH_TICKS {
            c.advance_to(THRASH_TICKS, opts);
        }
        let secs = t0.elapsed().as_secs_f64();
        let hash = event_log_hash(&c.events.snapshot());
        let shard_appends = c.events.shard_appends();
        let cs = c.coast_stats;
        if count == 1 {
            thrash_serial_secs = secs;
        }
        let vs_serial = thrash_serial_secs / secs.max(1e-9);
        if hash != thrash_ref_hash {
            thrash_hash_mismatch = true;
        }
        if cs.regions_entered == 0 {
            thrash_no_regions = true;
        }
        if count >= 2 {
            // the perf gate: parallel regions must never lose to serial
            // regions (5 % runner-noise tolerance); and the rung is only
            // meaningful if the parallel path actually engaged
            if secs > thrash_serial_secs * 1.05 {
                thrash_parallel_slow = true;
            }
            if cs.region_workers_max < 2 {
                thrash_not_parallel = true;
            }
        }
        println!(
            "  shards {count}: {secs:.3}s ({vs_serial:.2}x vs serial regions; lockstep \
             {thrash_lockstep_secs:.3}s), {} regions, workers mean {:.1} max {}, chunk {} \
             pods/worker, merge {:.4}s, log appends {shard_appends:?}, events hash \
             {hash:016x} {}",
            cs.regions_entered,
            cs.region_workers_mean(),
            cs.region_workers_max,
            cs.region_chunk_pods,
            cs.merge_nanos as f64 / 1e9,
            if hash == thrash_ref_hash { "(= lockstep)" } else { "(DIVERGED)" },
        );
        thrash_rows.push(obj(vec![
            ("threads", num(count as f64)),
            ("secs", num(secs)),
            ("ticks_per_sec", num(THRASH_TICKS as f64 / secs.max(1e-9))),
            ("speedup_vs_serial_regions", num(vs_serial)),
            ("event_log_hash", s(&format!("{hash:016x}"))),
            ("hash_matches_lockstep", Json::Bool(hash == thrash_ref_hash)),
            ("regions_entered", num(cs.regions_entered as f64)),
            ("region_exact_pod_ticks", num(cs.region_exact_pod_ticks as f64)),
            ("region_workers_max", num(cs.region_workers_max as f64)),
            ("region_workers_mean", num(cs.region_workers_mean())),
            // the adaptive chunk size the occupancy-derived splitter
            // settled on for this shard count (floor 128)
            ("region_chunk_pods", num(cs.region_chunk_pods as f64)),
            ("merge_secs", num(cs.merge_nanos as f64 / 1e9)),
            // per-shard append counts: how evenly the sharded store spread
            // the rung's record traffic across its watch shards
            (
                "shard_appends",
                arr(shard_appends.iter().map(|&a| num(a as f64)).collect()),
            ),
        ]));
    }

    let istats = intern_stats();
    let scale_json = obj(vec![
        ("bench", s("scenario_fleet/scale")),
        ("threads", num(threads as f64)),
        ("sharded_vs_serial_speedup_10k", num(speedup_10k)),
        ("intern_hits", num(istats.hits as f64)),
        ("intern_table_builds", num(istats.table_builds as f64)),
        ("rows", arr(scale_rows)),
        (
            "thrash",
            obj(vec![
                ("pods", num((THRASH_NODES * THRASH_PODS_PER_NODE) as f64)),
                ("nodes", num(THRASH_NODES as f64)),
                ("thrasher_frac", num(0.25)),
                ("event_shards", num(THRASH_EVENT_SHARDS as f64)),
                ("sim_ticks", num(THRASH_TICKS as f64)),
                ("lockstep_secs", num(thrash_lockstep_secs)),
                ("lockstep_hash", s(&format!("{thrash_ref_hash:016x}"))),
                ("rows", arr(thrash_rows)),
            ]),
        ),
    ]);
    std::fs::create_dir_all("bench_out").ok();
    std::fs::write("bench_out/BENCH_scale.json", scale_json.to_string_pretty())
        .expect("write bench_out/BENCH_scale.json");
    println!("\nwrote bench_out/BENCH_scale.json");

    let bench_json = obj(vec![
        ("bench", s("scenario_fleet")),
        ("threads", num(threads as f64)),
        ("scale_only", Json::Bool(scale_only)),
        ("serial_secs", num(grid_serial_s)),
        ("parallel_secs", num(grid_parallel_s)),
        ("grid_speedup", num(if grid_speedup.is_finite() { grid_speedup } else { 0.0 })),
        ("grid_speedup_required", num(grid_required)),
        ("parallel_identical", Json::Bool(grid_identical)),
        ("stuck_pending_total", num(stuck_total as f64)),
        ("unfinished_total", num(unfinished_total as f64)),
        ("kernel", kernel_json),
        ("scale", scale_json),
        ("singles", arr(singles.iter().map(outcome_json).collect())),
    ]);
    println!("\nBENCH {}", bench_json.to_string_pretty());

    if stuck_total > 0 {
        eprintln!("FAIL: {stuck_total} pods stuck Pending at drain");
        std::process::exit(1);
    }
    if unfinished_total > 0 {
        eprintln!("FAIL: {unfinished_total} jobs unfinished or dropped at the tick budget");
        std::process::exit(1);
    }
    if !grid_identical {
        eprintln!("FAIL: parallel grid diverged from serial reference");
        std::process::exit(1);
    }
    if !scale_only && threads >= 2 && grid_speedup < grid_required {
        eprintln!(
            "FAIL: parallel speedup {grid_speedup:.2}x below the {grid_required:.2}x required \
             on {threads} threads"
        );
        std::process::exit(1);
    }
    if !kernel_identical {
        eprintln!("FAIL: event-driven kernel diverged from the 1 s-stepping reference");
        std::process::exit(1);
    }
    // CI gate: never slower than the seed's per-second loop (target >= 5x
    // on the single-app sweep; the fleet scenario reports its own ratio)
    if kernel_speedup < 1.0 {
        eprintln!("FAIL: event kernel slower than 1 s stepping ({kernel_speedup:.2}x)");
        std::process::exit(1);
    }
    if scale_diverged {
        eprintln!("FAIL: a kernel flavor diverged from lockstep on the scale ladder");
        std::process::exit(1);
    }
    // CI gate: the sharded kernel must never be slower than the PR 3
    // serial event kernel at fleet scale (target >= 3x on the 10k rung;
    // the json records the actual ratio)
    if scale_sharded_slow {
        eprintln!("FAIL: sharded kernel slower than the serial event kernel at fleet scale");
        std::process::exit(1);
    }
    // PR 5 gate: the delta informer must never fall back to relisting
    // mid-run (the per-wake delta-vs-relist cost gate is perf_sim's
    // BENCH_informer; the ladder reports rebuilds-per-wake alongside)
    if informer_relisted {
        eprintln!("FAIL: the delta informer relisted after its initial LIST");
        std::process::exit(1);
    }
    // PR 8 gates: parallel stepping regions. The hash gate is the
    // determinism contract (shard-buffer merges must reproduce the serial
    // emission order bit-for-bit at every thread count); the speed gate
    // is the reason the regions shard at all.
    if thrash_hash_mismatch {
        eprintln!("FAIL: event-log hash diverged across region thread counts on the thrash rung");
        std::process::exit(1);
    }
    if thrash_parallel_slow {
        eprintln!("FAIL: parallel stepping regions slower than serial regions on the thrash rung");
        std::process::exit(1);
    }
    if thrash_no_regions {
        eprintln!("FAIL: the thrash rung never entered a stepping region");
        std::process::exit(1);
    }
    if thrash_not_parallel {
        eprintln!("FAIL: the thrash rung never engaged >= 2 region workers at >= 2 shards");
        std::process::exit(1);
    }
}
