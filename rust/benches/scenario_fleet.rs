//! Bench: fleet-scale scenario throughput + the parallel multi-seed
//! executor. Runs a 4-node, 36-job Poisson-arrival scenario (with a node
//! drain and a random kill) under ARC-V and the VPA simulator, times an
//! 8-seed ARC-V grid serially vs. in parallel (verifying the fan-out is
//! bit-identical to the serial reference), and then runs the fleet-SCALE
//! ladder: 1k/10k/100k-pod backlogs with one swap-thrashing leaker, under
//! {lockstep, serial event kernel, sharded kernel}, emitting
//! `bench_out/BENCH_scale.json` (ticks/s + wall-clock per cell).
//!
//!   cargo bench --bench scenario_fleet
//!
//! Set `SCALE_MAX_JOBS` to trim the ladder on small machines.
//!
//! Emits a machine-readable `BENCH {json}` block at the end. Exits
//! non-zero if any pod is stuck Pending at drain, the parallel grid
//! diverges from the serial one, any kernel flavor diverges from
//! lockstep on the scale ladder, or the sharded kernel is slower than
//! the serial event kernel there (the fleet-scale regression gate).

use arcv::harness::SwapKind;
use arcv::policy::arcv::ArcvParams;
use arcv::scenario::{
    outcome_json, outcome_line, run_grid, run_scenario, run_scenario_mode, summarize,
    summary_line, Arrivals, Fault, ScenarioPolicy, ScenarioSpec, WorkloadMix,
};
use arcv::simkube::KernelMode;
use arcv::util::json::{arr, num, obj, s, Json};
use arcv::workloads::AppId;
use std::time::Instant;

fn fleet_spec() -> ScenarioSpec {
    // Heterogeneous pools: two paper-spec 256 GB workers + two small 96 GB
    // workers. 36 jobs arrive Poisson at 4/min (~9 min submission window);
    // mid-run one small node drains and one random pod is killed.
    ScenarioSpec::new("fleet-poisson")
        .pool("big", 2, 256.0, SwapKind::Hdd(128.0))
        .pool("small", 2, 96.0, SwapKind::Ssd(32.0))
        .arrivals(Arrivals::Poisson { rate_per_min: 4.0 })
        .jobs(36)
        .mix(WorkloadMix::uniform(&[
            AppId::Amr,
            AppId::Bfs,
            AppId::Cm1,
            AppId::Kripke,
            AppId::Lulesh,
            AppId::Minife,
            AppId::Sputnipic,
        ]))
        .fault(Fault::KillRandomPod { at: 300 })
        .fault(Fault::DrainNode { at: 600, node: 3 })
        .max_ticks(120_000)
}

/// One rung of the fleet-scale ladder: `jobs` flat-start jobs from the
/// three smooth Growth apps (so coast windows stay long), one node per
/// ~10 jobs, plus a mid-life leaker that outgrows its 120 % limit at
/// t ≈ 85 and thrashes in swap for the rest of the run — the mixed
/// cluster that used to collapse the whole fleet to 1 s stepping.
fn scale_spec(jobs: usize) -> ScenarioSpec {
    let nodes = (jobs / 10).max(1);
    ScenarioSpec::new(&format!("scale-{jobs}"))
        .pool("w", nodes, 64.0, SwapKind::Hdd(32.0))
        .mix(WorkloadMix::uniform(&[AppId::Amr, AppId::Cm1, AppId::Sputnipic]))
        .arrivals(Arrivals::Backlog)
        .jobs(jobs)
        .fault(Fault::LeakyPod {
            at: 60,
            base_gb: 2.0,
            leak_gb_per_sec: 0.02,
            lifetime_secs: 3_000.0,
        })
        // rings are preallocated per sampled pod: keep them shallow at
        // fleet scale (nothing scrapes them under the fixed policy)
        .metrics_history(64)
        .max_ticks(if jobs >= 100_000 { 1_000 } else { 2_000 })
}

/// Run one `(spec, mode)` cell, returning (wall secs, outcome, events,
/// sim ticks) — the cluster itself is dropped so three 100k-pod runs
/// never coexist in memory.
fn scale_cell(
    spec: &ScenarioSpec,
    mode: KernelMode,
) -> (f64, arcv::scenario::ScenarioOutcome, Vec<arcv::simkube::Event>, u64) {
    let t0 = Instant::now();
    let run = run_scenario_mode(spec, ScenarioPolicy::Fixed, 42, mode);
    let secs = t0.elapsed().as_secs_f64();
    (secs, run.outcome, run.cluster.events.events, run.stats.sim_ticks)
}

fn main() {
    let spec = fleet_spec();
    let policies = [
        ScenarioPolicy::Arcv(ArcvParams::default()),
        ScenarioPolicy::VpaSim,
    ];

    println!("=== single-seed fleet scenario: ARC-V vs VPA-sim ===\n");
    let mut singles = Vec::new();
    let mut stuck_total = 0usize;
    let mut unfinished_total = 0usize;
    for policy in policies {
        let t0 = Instant::now();
        let run = run_scenario(&spec, policy, 42);
        let secs = t0.elapsed().as_secs_f64();
        println!("{}   ({secs:.2}s wall)", outcome_line(&run.outcome));
        stuck_total += run.outcome.stuck_pending;
        // a truncated or livelocked run must fail loudly, not slip past a
        // stuck-Pending-only gate
        unfinished_total += run.outcome.unfinished + run.outcome.jobs_dropped;
        singles.push(run.outcome);
    }
    let arcv = &singles[0];
    let vpa = &singles[1];
    if arcv.used_gb_h > 0.0 && vpa.used_gb_h > 0.0 {
        println!(
            "\nallocated/used: arcv {:.2}x  vpa-sim {:.2}x  (reclaimed capacity is what \
             admits more queued work per node)",
            arcv.allocated_gb_h / arcv.used_gb_h,
            vpa.allocated_gb_h / vpa.used_gb_h,
        );
    }

    println!("\n=== kernel: event-driven clock vs 1 s-stepping on the fleet scenario ===\n");
    let arcv_policy = ScenarioPolicy::Arcv(ArcvParams::default());
    let t0 = Instant::now();
    let lockstep_run = run_scenario_mode(&spec, arcv_policy, 42, KernelMode::Lockstep);
    let kernel_lockstep_secs = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let event_run = run_scenario_mode(&spec, arcv_policy, 42, KernelMode::EventDriven);
    let kernel_event_secs = t0.elapsed().as_secs_f64();
    let kernel_identical = lockstep_run.outcome == event_run.outcome
        && lockstep_run.cluster.events.events == event_run.cluster.events.events;
    let kernel_speedup = kernel_lockstep_secs / kernel_event_secs.max(1e-9);
    let ticks = event_run.stats.sim_ticks;
    println!(
        "lockstep {kernel_lockstep_secs:.3}s  event {kernel_event_secs:.3}s over {ticks} \
         sim-seconds -> {kernel_speedup:.2}x speedup, {} kernel events, results {}",
        event_run.stats.events,
        if kernel_identical { "bit-identical" } else { "DIVERGED" },
    );
    let kernel_json = obj(vec![
        ("bench", s("scenario_fleet/kernel")),
        ("sim_ticks", num(ticks as f64)),
        ("kernel_events", num(event_run.stats.events as f64)),
        ("ctl_wakes", num(event_run.stats.ctl_wakes as f64)),
        ("lockstep_secs", num(kernel_lockstep_secs)),
        ("event_secs", num(kernel_event_secs)),
        ("speedup", num(kernel_speedup)),
        ("events_per_sec", num(event_run.stats.events as f64 / kernel_event_secs.max(1e-9))),
        ("ticks_per_sec_event", num(ticks as f64 / kernel_event_secs.max(1e-9))),
        ("identical", Json::Bool(kernel_identical)),
    ]);
    std::fs::create_dir_all("bench_out").ok();
    std::fs::write("bench_out/BENCH_kernel_fleet.json", kernel_json.to_string_pretty())
        .expect("write bench_out/BENCH_kernel_fleet.json");

    println!("\n=== parallel multi-seed executor: 8 ARC-V seeds, serial vs parallel ===\n");
    let seeds: Vec<u64> = (1..=8).collect();
    let grid_policies = [ScenarioPolicy::Arcv(ArcvParams::default())];
    let specs = [fleet_spec()];

    let t0 = Instant::now();
    let serial = run_grid(&specs, &grid_policies, &seeds, 1);
    let serial_s = t0.elapsed().as_secs_f64();

    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let t0 = Instant::now();
    let parallel = run_grid(&specs, &grid_policies, &seeds, 0);
    let parallel_s = t0.elapsed().as_secs_f64();

    let identical = serial == parallel;
    let speedup = serial_s / parallel_s.max(1e-9);
    // parallelism-aware gate: a fully-serialized executor regression shows
    // up as ~1.0x on any machine, so require scaling proportional to the
    // cores actually available (on >=8 cores this demands the >=3x of the
    // acceptance criterion; on a 2-core box it still catches serialization)
    let eff_threads = threads.min(seeds.len()) as f64;
    let required = 1.0 + 0.3 * (eff_threads - 1.0);
    println!("serial:   {serial_s:.2}s for {} runs", serial.len());
    println!(
        "parallel: {parallel_s:.2}s on {threads} threads  -> {speedup:.2}x speedup \
         (required >= {required:.2}x)"
    );
    println!(
        "parallel results {} the serial reference",
        if identical { "bit-identical to" } else { "DIVERGE FROM" }
    );
    for line in summarize(&serial).iter().map(summary_line) {
        println!("{line}");
    }
    let grid_stuck: usize = serial.iter().map(|o| o.stuck_pending).sum();
    let grid_unfinished: usize = serial.iter().map(|o| o.unfinished + o.jobs_dropped).sum();

    println!("\n=== fleet scale: sharded vs serial event kernel vs lockstep ===\n");
    let scale_max: usize = std::env::var("SCALE_MAX_JOBS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(100_000);
    let mut scale_rows = Vec::new();
    let mut scale_diverged = false;
    let mut scale_sharded_slow = false;
    // 0.0 = "rung not run" (SCALE_MAX_JOBS trimmed it) — keeps the json valid
    let mut speedup_10k = 0.0_f64;
    for jobs in [1_000usize, 10_000, 100_000] {
        if jobs > scale_max {
            println!("  (skipping {jobs}-pod rung: SCALE_MAX_JOBS={scale_max})");
            continue;
        }
        let sspec = scale_spec(jobs);
        // one run in memory at a time: each cell drops its cluster
        let (lock_secs, lock_out, lock_events, _) = scale_cell(&sspec, KernelMode::Lockstep);
        let (serial_secs, serial_out, serial_events, _) =
            scale_cell(&sspec, KernelMode::EventDriven);
        let (shard_secs, shard_out, shard_events, ticks) =
            scale_cell(&sspec, KernelMode::Sharded { threads: 0 });
        let identical = lock_out == serial_out
            && lock_out == shard_out
            && lock_events == serial_events
            && lock_events == shard_events;
        if !identical {
            scale_diverged = true;
        }
        let vs_serial = serial_secs / shard_secs.max(1e-9);
        let vs_lockstep = lock_secs / shard_secs.max(1e-9);
        if jobs == 10_000 {
            speedup_10k = vs_serial;
        }
        // the regression gate: sharded must never be slower than the
        // PR 3 serial event kernel (5 % tolerance for runner noise)
        if shard_secs > serial_secs * 1.05 {
            scale_sharded_slow = true;
        }
        println!(
            "  {jobs:>6} pods over {ticks} sim-s: lockstep {lock_secs:>7.2}s  serial-event \
             {serial_secs:>7.2}s  sharded {shard_secs:>7.2}s  -> {vs_serial:.2}x vs serial, \
             {vs_lockstep:.2}x vs lockstep, {}",
            if identical { "bit-identical" } else { "DIVERGED" },
        );
        scale_rows.push(obj(vec![
            ("jobs", num(jobs as f64)),
            ("nodes", num(sspec.node_count() as f64)),
            ("sim_ticks", num(ticks as f64)),
            ("lockstep_secs", num(lock_secs)),
            ("serial_event_secs", num(serial_secs)),
            ("sharded_secs", num(shard_secs)),
            ("sharded_vs_serial_speedup", num(vs_serial)),
            ("sharded_vs_lockstep_speedup", num(vs_lockstep)),
            ("ticks_per_sec_lockstep", num(ticks as f64 / lock_secs.max(1e-9))),
            ("ticks_per_sec_serial_event", num(ticks as f64 / serial_secs.max(1e-9))),
            ("ticks_per_sec_sharded", num(ticks as f64 / shard_secs.max(1e-9))),
            ("identical", Json::Bool(identical)),
        ]));
    }
    let scale_json = obj(vec![
        ("bench", s("scenario_fleet/scale")),
        ("threads", num(threads as f64)),
        ("sharded_vs_serial_speedup_10k", num(speedup_10k)),
        ("rows", arr(scale_rows)),
    ]);
    std::fs::create_dir_all("bench_out").ok();
    std::fs::write("bench_out/BENCH_scale.json", scale_json.to_string_pretty())
        .expect("write bench_out/BENCH_scale.json");
    println!("\nwrote bench_out/BENCH_scale.json");

    let bench_json = obj(vec![
        ("bench", s("scenario_fleet")),
        ("nodes", num(spec.node_count() as f64)),
        ("jobs", num(spec.jobs as f64)),
        ("threads", num(threads as f64)),
        ("serial_secs", num(serial_s)),
        ("parallel_secs", num(parallel_s)),
        ("speedup", num(speedup)),
        ("speedup_required", num(required)),
        ("parallel_identical", Json::Bool(identical)),
        ("stuck_pending_total", num((stuck_total + grid_stuck) as f64)),
        ("unfinished_total", num((unfinished_total + grid_unfinished) as f64)),
        ("kernel", kernel_json),
        ("scale", scale_json),
        ("singles", arr(singles.iter().map(outcome_json).collect())),
    ]);
    println!("\nBENCH {}", bench_json.to_string_pretty());

    if stuck_total + grid_stuck > 0 {
        eprintln!("FAIL: {} pods stuck Pending at drain", stuck_total + grid_stuck);
        std::process::exit(1);
    }
    if unfinished_total + grid_unfinished > 0 {
        eprintln!(
            "FAIL: {} jobs unfinished or dropped at the tick budget",
            unfinished_total + grid_unfinished
        );
        std::process::exit(1);
    }
    if !identical {
        eprintln!("FAIL: parallel grid diverged from serial reference");
        std::process::exit(1);
    }
    if threads >= 2 && speedup < required {
        eprintln!(
            "FAIL: parallel speedup {speedup:.2}x below the {required:.2}x required \
             on {threads} threads"
        );
        std::process::exit(1);
    }
    if !kernel_identical {
        eprintln!("FAIL: event-driven kernel diverged from the 1 s-stepping reference");
        std::process::exit(1);
    }
    // CI gate: never slower than the seed's per-second loop (target >= 5x
    // on the single-app sweep; the fleet scenario reports its own ratio)
    if kernel_speedup < 1.0 {
        eprintln!("FAIL: event kernel slower than 1 s stepping ({kernel_speedup:.2}x)");
        std::process::exit(1);
    }
    if scale_diverged {
        eprintln!("FAIL: a kernel flavor diverged from lockstep on the scale ladder");
        std::process::exit(1);
    }
    // CI gate: the sharded kernel must never be slower than the PR 3
    // serial event kernel at fleet scale (target >= 3x on the 10k rung;
    // the json records the actual ratio)
    if scale_sharded_slow {
        eprintln!("FAIL: sharded kernel slower than the serial event kernel at fleet scale");
        std::process::exit(1);
    }
}
