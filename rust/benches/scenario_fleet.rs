//! Bench: fleet-scale scenario throughput + the parallel multi-seed
//! executor. Runs a 4-node, 36-job Poisson-arrival scenario (with a node
//! drain and a random kill) under ARC-V and the VPA simulator, then times
//! an 8-seed ARC-V grid serially vs. in parallel and verifies the fan-out
//! is bit-identical to the serial reference.
//!
//!   cargo bench --bench scenario_fleet
//!
//! Emits a machine-readable `BENCH {json}` block at the end. Exits
//! non-zero if any pod is stuck Pending at drain or the parallel grid
//! diverges from the serial one.

use arcv::harness::SwapKind;
use arcv::policy::arcv::ArcvParams;
use arcv::scenario::{
    outcome_json, outcome_line, run_grid, run_scenario, run_scenario_mode, summarize,
    summary_line, Arrivals, Fault, ScenarioPolicy, ScenarioSpec, WorkloadMix,
};
use arcv::simkube::KernelMode;
use arcv::util::json::{arr, num, obj, s, Json};
use arcv::workloads::AppId;
use std::time::Instant;

fn fleet_spec() -> ScenarioSpec {
    // Heterogeneous pools: two paper-spec 256 GB workers + two small 96 GB
    // workers. 36 jobs arrive Poisson at 4/min (~9 min submission window);
    // mid-run one small node drains and one random pod is killed.
    ScenarioSpec::new("fleet-poisson")
        .pool("big", 2, 256.0, SwapKind::Hdd(128.0))
        .pool("small", 2, 96.0, SwapKind::Ssd(32.0))
        .arrivals(Arrivals::Poisson { rate_per_min: 4.0 })
        .jobs(36)
        .mix(WorkloadMix::uniform(&[
            AppId::Amr,
            AppId::Bfs,
            AppId::Cm1,
            AppId::Kripke,
            AppId::Lulesh,
            AppId::Minife,
            AppId::Sputnipic,
        ]))
        .fault(Fault::KillRandomPod { at: 300 })
        .fault(Fault::DrainNode { at: 600, node: 3 })
        .max_ticks(120_000)
}

fn main() {
    let spec = fleet_spec();
    let policies = [
        ScenarioPolicy::Arcv(ArcvParams::default()),
        ScenarioPolicy::VpaSim,
    ];

    println!("=== single-seed fleet scenario: ARC-V vs VPA-sim ===\n");
    let mut singles = Vec::new();
    let mut stuck_total = 0usize;
    let mut unfinished_total = 0usize;
    for policy in policies {
        let t0 = Instant::now();
        let run = run_scenario(&spec, policy, 42);
        let secs = t0.elapsed().as_secs_f64();
        println!("{}   ({secs:.2}s wall)", outcome_line(&run.outcome));
        stuck_total += run.outcome.stuck_pending;
        // a truncated or livelocked run must fail loudly, not slip past a
        // stuck-Pending-only gate
        unfinished_total += run.outcome.unfinished + run.outcome.jobs_dropped;
        singles.push(run.outcome);
    }
    let arcv = &singles[0];
    let vpa = &singles[1];
    if arcv.used_gb_h > 0.0 && vpa.used_gb_h > 0.0 {
        println!(
            "\nallocated/used: arcv {:.2}x  vpa-sim {:.2}x  (reclaimed capacity is what \
             admits more queued work per node)",
            arcv.allocated_gb_h / arcv.used_gb_h,
            vpa.allocated_gb_h / vpa.used_gb_h,
        );
    }

    println!("\n=== kernel: event-driven clock vs 1 s-stepping on the fleet scenario ===\n");
    let arcv_policy = ScenarioPolicy::Arcv(ArcvParams::default());
    let t0 = Instant::now();
    let lockstep_run = run_scenario_mode(&spec, arcv_policy, 42, KernelMode::Lockstep);
    let kernel_lockstep_secs = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let event_run = run_scenario_mode(&spec, arcv_policy, 42, KernelMode::EventDriven);
    let kernel_event_secs = t0.elapsed().as_secs_f64();
    let kernel_identical = lockstep_run.outcome == event_run.outcome
        && lockstep_run.cluster.events.events == event_run.cluster.events.events;
    let kernel_speedup = kernel_lockstep_secs / kernel_event_secs.max(1e-9);
    let ticks = event_run.stats.sim_ticks;
    println!(
        "lockstep {kernel_lockstep_secs:.3}s  event {kernel_event_secs:.3}s over {ticks} \
         sim-seconds -> {kernel_speedup:.2}x speedup, {} kernel events, results {}",
        event_run.stats.events,
        if kernel_identical { "bit-identical" } else { "DIVERGED" },
    );
    let kernel_json = obj(vec![
        ("bench", s("scenario_fleet/kernel")),
        ("sim_ticks", num(ticks as f64)),
        ("kernel_events", num(event_run.stats.events as f64)),
        ("ctl_wakes", num(event_run.stats.ctl_wakes as f64)),
        ("lockstep_secs", num(kernel_lockstep_secs)),
        ("event_secs", num(kernel_event_secs)),
        ("speedup", num(kernel_speedup)),
        ("events_per_sec", num(event_run.stats.events as f64 / kernel_event_secs.max(1e-9))),
        ("ticks_per_sec_event", num(ticks as f64 / kernel_event_secs.max(1e-9))),
        ("identical", Json::Bool(kernel_identical)),
    ]);
    std::fs::create_dir_all("bench_out").ok();
    std::fs::write("bench_out/BENCH_kernel_fleet.json", kernel_json.to_string_pretty())
        .expect("write bench_out/BENCH_kernel_fleet.json");

    println!("\n=== parallel multi-seed executor: 8 ARC-V seeds, serial vs parallel ===\n");
    let seeds: Vec<u64> = (1..=8).collect();
    let grid_policies = [ScenarioPolicy::Arcv(ArcvParams::default())];
    let specs = [fleet_spec()];

    let t0 = Instant::now();
    let serial = run_grid(&specs, &grid_policies, &seeds, 1);
    let serial_s = t0.elapsed().as_secs_f64();

    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let t0 = Instant::now();
    let parallel = run_grid(&specs, &grid_policies, &seeds, 0);
    let parallel_s = t0.elapsed().as_secs_f64();

    let identical = serial == parallel;
    let speedup = serial_s / parallel_s.max(1e-9);
    // parallelism-aware gate: a fully-serialized executor regression shows
    // up as ~1.0x on any machine, so require scaling proportional to the
    // cores actually available (on >=8 cores this demands the >=3x of the
    // acceptance criterion; on a 2-core box it still catches serialization)
    let eff_threads = threads.min(seeds.len()) as f64;
    let required = 1.0 + 0.3 * (eff_threads - 1.0);
    println!("serial:   {serial_s:.2}s for {} runs", serial.len());
    println!(
        "parallel: {parallel_s:.2}s on {threads} threads  -> {speedup:.2}x speedup \
         (required >= {required:.2}x)"
    );
    println!(
        "parallel results {} the serial reference",
        if identical { "bit-identical to" } else { "DIVERGE FROM" }
    );
    for line in summarize(&serial).iter().map(summary_line) {
        println!("{line}");
    }
    let grid_stuck: usize = serial.iter().map(|o| o.stuck_pending).sum();
    let grid_unfinished: usize = serial.iter().map(|o| o.unfinished + o.jobs_dropped).sum();

    let bench_json = obj(vec![
        ("bench", s("scenario_fleet")),
        ("nodes", num(spec.node_count() as f64)),
        ("jobs", num(spec.jobs as f64)),
        ("threads", num(threads as f64)),
        ("serial_secs", num(serial_s)),
        ("parallel_secs", num(parallel_s)),
        ("speedup", num(speedup)),
        ("speedup_required", num(required)),
        ("parallel_identical", Json::Bool(identical)),
        ("stuck_pending_total", num((stuck_total + grid_stuck) as f64)),
        ("unfinished_total", num((unfinished_total + grid_unfinished) as f64)),
        ("kernel", kernel_json),
        ("singles", arr(singles.iter().map(outcome_json).collect())),
    ]);
    println!("\nBENCH {}", bench_json.to_string_pretty());

    if stuck_total + grid_stuck > 0 {
        eprintln!("FAIL: {} pods stuck Pending at drain", stuck_total + grid_stuck);
        std::process::exit(1);
    }
    if unfinished_total + grid_unfinished > 0 {
        eprintln!(
            "FAIL: {} jobs unfinished or dropped at the tick budget",
            unfinished_total + grid_unfinished
        );
        std::process::exit(1);
    }
    if !identical {
        eprintln!("FAIL: parallel grid diverged from serial reference");
        std::process::exit(1);
    }
    if threads >= 2 && speedup < required {
        eprintln!(
            "FAIL: parallel speedup {speedup:.2}x below the {required:.2}x required \
             on {threads} threads"
        );
        std::process::exit(1);
    }
    if !kernel_identical {
        eprintln!("FAIL: event-driven kernel diverged from the 1 s-stepping reference");
        std::process::exit(1);
    }
    // CI gate: never slower than the seed's per-second loop (target >= 5x
    // on the single-app sweep; the fleet scenario reports its own ratio)
    if kernel_speedup < 1.0 {
        eprintln!("FAIL: event kernel slower than 1 s stepping ({kernel_speedup:.2}x)");
        std::process::exit(1);
    }
}
