//! Ablation bench — the design-choice studies DESIGN.md calls out:
//!
//! - `fig1`   static bare-metal allocation vs elastic ARC-V (the paper's
//!            Figure 1 concept, quantified)
//! - `params` stability-factor sweep (§4.2)
//! - `window` measurement-window sweep (§4.2)
//! - `oracle` ARC-V vs the clairvoyant lower bound
//! - `swap`   device-class study on MiniFE (HDD vs SSD vs none, §3.2)
//!
//!   cargo bench --bench ablation [-- <scene>]   (default: all)

use arcv::harness::{run, run_line, ExperimentConfig, PolicyKind, SwapKind};
use arcv::policy::arcv::ArcvParams;
use arcv::util::plot::bars;
use arcv::workloads::AppId;

fn main() {
    let scene = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-'))
        .unwrap_or_else(|| "all".to_string());
    if scene == "fig1" || scene == "all" {
        fig1();
    }
    if scene == "params" || scene == "all" {
        params_sweep();
    }
    if scene == "window" || scene == "all" {
        window_sweep();
    }
    if scene == "oracle" || scene == "all" {
        oracle_gap();
    }
    if scene == "swap" || scene == "all" {
        swap_study();
    }
}

fn fig1() {
    println!("=== Fig 1 concept: static HPC allocation vs elastic ARC-V (kripke) ===\n");
    // static: reserve the whole paper node (256GB) for the job
    let mut cfg = ExperimentConfig::arcv_env(AppId::Kripke);
    cfg.initial_frac = 256.0 / 5.5; // whole node
    let fixed = run(&cfg, PolicyKind::Fixed);
    let arcv = run(
        &ExperimentConfig::arcv_env(AppId::Kripke),
        PolicyKind::ArcvNative(ArcvParams::default()),
    );
    println!("  {}", run_line(&fixed));
    println!("  {}", run_line(&arcv));
    println!(
        "\n  bare-metal reserves {:.1} GB·s; ARC-V provisions {:.1} GB·s -> {:.1}x saving\n",
        fixed.provisioned_gbs,
        arcv.provisioned_gbs,
        fixed.provisioned_gbs / arcv.provisioned_gbs
    );
}

fn params_sweep() {
    println!("=== §4.2 ablation: stability factor (kripke + lulesh) ===\n");
    let mut rows = Vec::new();
    for sf in [0.005, 0.01, 0.02, 0.05, 0.10] {
        let mut p = ArcvParams::default();
        p.stability = sf;
        for app in [AppId::Kripke, AppId::Lulesh] {
            let r = run(&ExperimentConfig::arcv_env(app), PolicyKind::ArcvNative(p));
            rows.push((
                format!("{}/sf={:.1}%", app.name(), sf * 100.0),
                r.provisioned_gbs / r.used_gbs,
            ));
            println!(
                "  sf={:<5} {:<8} fp/used={:.3} ooms={} wall={}s",
                sf,
                app.name(),
                r.provisioned_gbs / r.used_gbs,
                r.oom_count,
                r.wall_secs
            );
        }
    }
    let refs: Vec<(&str, f64)> = rows.iter().map(|(s, v)| (s.as_str(), *v)).collect();
    print!("\n{}", bars("provisioned/used ratio (lower = tighter)", &refs, 40));
    println!();
}

fn window_sweep() {
    println!("=== §4.2 ablation: measurement window (kripke) ===\n");
    for w in [6usize, 12, 24] {
        let mut p = ArcvParams::default();
        p.window = w;
        p.horizon_samples = w as f64;
        let r = run(&ExperimentConfig::arcv_env(AppId::Kripke), PolicyKind::ArcvNative(p));
        println!(
            "  window={:<3} fp={:.1} GB·s overhead={:+.2}% ooms={}",
            w,
            r.provisioned_gbs,
            (r.wall_secs as f64 / 650.0 - 1.0) * 100.0,
            r.oom_count
        );
    }
    println!();
}

fn oracle_gap() {
    println!("=== ablation: ARC-V vs clairvoyant oracle ===\n");
    for app in [AppId::Kripke, AppId::Cm1, AppId::Lulesh, AppId::Sputnipic] {
        let arcv = run(
            &ExperimentConfig::arcv_env(app),
            PolicyKind::ArcvNative(ArcvParams::default()),
        );
        let oracle = run(&ExperimentConfig::arcv_env(app), PolicyKind::Oracle);
        println!(
            "  {:<10} arcv={:>10.1} GB·s oracle={:>10.1} GB·s gap={:.2}x",
            app.name(),
            arcv.provisioned_gbs,
            oracle.provisioned_gbs,
            arcv.provisioned_gbs / oracle.provisioned_gbs
        );
    }
    println!();
}

fn swap_study() {
    println!("=== §3.2 ablation: swap device class on MiniFE's end spike ===\n");
    for (label, swap) in [
        ("hdd(0.1GB/s)", SwapKind::Hdd(128.0)),
        ("ssd(1GB/s)", SwapKind::Ssd(128.0)),
        ("disabled", SwapKind::Disabled),
    ] {
        let mut cfg = ExperimentConfig::arcv_env(AppId::Minife);
        cfg.initial_frac = 0.9; // limit below the end spike -> swap matters
        cfg.swap = swap;
        cfg.budget_mult = 30.0;
        let r = run(&cfg, PolicyKind::ArcvNative(ArcvParams::default()));
        println!(
            "  {:<14} wall={:>5}s (nominal 352s) ooms={} restarts={} {}",
            label,
            r.wall_secs,
            r.oom_count,
            r.restarts,
            if r.completed { "done" } else { "TIMEOUT" }
        );
    }
    println!("\n  (without swap the spike OOMs; device bandwidth sets the overhead)");
}
