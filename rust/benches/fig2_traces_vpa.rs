//! Bench: regenerate **Figure 2** — the 5 s-sampled memory-consumption
//! series of all nine applications with the VPA Recommender's line
//! (updates disabled), reproducing the slow-adaptation behaviour §2.3
//! reports. CSV series land in bench_out/fig2_<app>.csv.
//!
//!   cargo bench --bench fig2_traces_vpa

use arcv::policy::vpa::HistogramRecommender;
use arcv::util::csv::CsvWriter;
use arcv::util::plot::multi_line;
use arcv::workloads::{build, Trace, TABLE1};

fn main() {
    std::fs::create_dir_all("bench_out").ok();
    println!("=== Figure 2: memory consumption + VPA recommendation ===");
    for row in &TABLE1 {
        let model = build(row.app, 42);
        let trace = Trace::from_model(&model, 5.0);

        // The VPA Recommender consumes the same samples it would scrape.
        let mut rec = HistogramRecommender::new();
        let mut rec_series = Vec::with_capacity(trace.samples.len());
        for (i, &u) in trace.samples.iter().enumerate() {
            rec.add_sample(i as u64 * 5, u);
            rec_series.push(rec.recommend_gb());
        }

        let mut csv = CsvWriter::new(&["t_secs", "usage_gb", "vpa_recommendation_gb"]);
        for (i, (&u, &r)) in trace.samples.iter().zip(&rec_series).enumerate() {
            csv.frow(&[i as f64 * 5.0, u, r]);
        }
        let path = format!("bench_out/fig2_{}.csv", row.app.name());
        csv.save(&path).expect("write fig2 csv");

        println!();
        print!(
            "{}",
            multi_line(
                &format!(
                    "{} — usage vs VPA recommendation (GB, {} samples) -> {}",
                    row.app.name(),
                    trace.samples.len(),
                    path
                ),
                &[("usage", &trace.samples), ("vpa-rec", &rec_series)],
                100,
                14,
            )
        );

        // §2.3's core claim: VPA "relies on historical patterns, which are
        // inconsistent in HPC workloads due to varying input characteristics".
        // Feed the recommender a full run, then replay the same app with a
        // 30% larger input: the historical recommendation undershoots and,
        // if enforced (§4.1 semantics: static until OOM, +20% per restart),
        // the app OOMs repeatedly.
        let hist_rec = rec.recommend_gb();
        let mut enforced = hist_rec;
        let mut ooms = 0;
        for &u in &trace.samples {
            let scaled = u * 1.3; // next input is 30% bigger
            if scaled > enforced {
                ooms += 1;
                enforced = scaled * 1.2; // the §4.1 restart bump
            }
        }
        println!(
            "  next-run (1.3x input): historical rec {:.2} GB -> {} enforced OOM restarts ({})",
            hist_rec,
            ooms,
            if ooms > 0 {
                "history misleads on varying inputs, as §2.3 reports"
            } else {
                "covered"
            }
        );
    }
}
