//! Perf bench: decision-tick latency of the ARC-V hot path.
//!
//! Compares the native fleet backend against the AOT XLA artifact (PJRT)
//! across fleet sizes, plus the per-component micro-costs (signal
//! detection, forecast). Feeds EXPERIMENTS.md §Perf.
//!
//!   cargo bench --bench perf_tick

use arcv::policy::arcv::forecast::forecast;
use arcv::policy::arcv::{detect, ArcvParams, DecisionBackend, NativeFleet, PodState, STATE_LEN};
use arcv::runtime::{Engine, Manifest, XlaFleet};
use arcv::util::bench::bench_auto;
use arcv::util::rng::Xoshiro256;

fn batch(rng: &mut Xoshiro256, n: usize, w: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut windows = vec![0f32; n * w];
    let mut swap = vec![0f32; n];
    let mut states = vec![0f32; n * STATE_LEN];
    for i in 0..n {
        let base = rng.uniform(0.1, 50.0);
        for j in 0..w {
            windows[i * w + j] = (base * rng.uniform(0.9, 1.1)) as f32;
        }
        swap[i] = 0.0;
        PodState::initial(base * 1.2).pack(&mut states[i * STATE_LEN..(i + 1) * STATE_LEN]);
    }
    (windows, swap, states)
}

fn main() {
    let params = ArcvParams::default();
    let w = params.window;
    let mut rng = Xoshiro256::new(1);

    println!("=== micro: signal detection + forecast (native, per window) ===");
    let win: Vec<f64> = (0..w).map(|i| 4.0 + 0.05 * i as f64).collect();
    bench_auto("native/detect(window=12)", 60.0, || detect(&win, 0.02));
    bench_auto("native/forecast(window=12)", 60.0, || forecast(&win, 12.0));

    println!("\n=== fleet decision tick: native backend ===");
    for n in [1usize, 8, 64, 256] {
        let mut fleet = NativeFleet::new(n, w);
        let (windows, swap, states) = batch(&mut rng, n, w);
        let mut st = states.clone();
        let r = bench_auto(&format!("native-fleet/step n={n}"), 120.0, || {
            st.copy_from_slice(&states);
            fleet.step(n, &windows, &swap, &mut st, &params).unwrap()
        });
        println!("    -> {:.2} M pod-decisions/s", r.per_sec(n as f64) / 1e6);
    }

    println!("\n=== fleet decision tick: XLA artifact backend (PJRT CPU) ===");
    match Manifest::discover() {
        Ok(manifest) => {
            let engine = Engine::cpu().expect("PJRT CPU client");
            for n in [1usize, 8, 64, 256] {
                let mut fleet = XlaFleet::from_manifest(&engine, &manifest, n)
                    .expect("load arcv_step artifact");
                let (windows, swap, states) = batch(&mut rng, n, w);
                let mut st = states.clone();
                let r = bench_auto(
                    &format!("xla-fleet/step n={n} (batch={})", fleet.batch()),
                    200.0,
                    || {
                        st.copy_from_slice(&states);
                        fleet.step(n, &windows, &swap, &mut st, &params).unwrap()
                    },
                );
                println!("    -> {:.2} k pod-decisions/s", r.per_sec(n as f64) / 1e3);
            }
            println!(
                "\nnote: PJRT-CPU pays per-execute dispatch; on the paper's 5s \
                 sampling / 60s decisions, even the n=256 tick is ~1e5x faster \
                 than its deadline."
            );
        }
        Err(e) => println!("skipping XLA backend ({e})"),
    }
}
