//! Bench: regenerate **Table 1** — pattern class, execution time, max
//! memory, and memory footprint for all nine applications — and time the
//! trace generator itself.
//!
//!   cargo bench --bench table1

use arcv::util::bench::bench_auto;
use arcv::util::units::fmt_gb;
use arcv::workloads::{build, check, Trace, TABLE1};

fn main() {
    println!("=== Table 1 reproduction (paper values in parentheses) ===\n");
    println!(
        "{:<12} {:>7} {:>12} {:>22} {:>26}",
        "Application", "Pattern", "Exec Time", "Max. Memory", "Memory Footprint"
    );
    println!("{}", "-".repeat(84));
    let mut all_ok = true;
    for row in &TABLE1 {
        let rep = check(row, 42);
        all_ok &= rep.within(0.05);
        println!(
            "{:<12} {:>4}({}) {:>8}s ({:>5}s) {:>10} ({:>8}) {:>11.2} TB·s ({:>6.2} TB)",
            row.app.name(),
            rep.measured_pattern,
            row.pattern,
            row.exec_secs as u64,
            row.exec_secs as u64,
            fmt_gb(rep.measured_max_gb),
            fmt_gb(row.max_gb),
            rep.measured_footprint_gbs / 1000.0,
            row.footprint_gbs / 1000.0,
        );
    }
    println!(
        "\ncalibration: {}",
        if all_ok { "all rows within ±5%" } else { "OUT OF TOLERANCE" }
    );

    println!("\n=== trace-generation performance ===\n");
    for row in &TABLE1 {
        let model = build(row.app, 42);
        let r = bench_auto(&format!("trace/{}", row.app.name()), 80.0, || {
            Trace::from_model(&model, 5.0)
        });
        let samples = (row.exec_secs / 5.0) as f64;
        println!(
            "    -> {:.1} M samples/s",
            r.per_sec(samples) / 1e6
        );
    }
    if !all_ok {
        std::process::exit(1);
    }
}
