//! Bench: regenerate **Figure 4** — (left) the per-application ratios of
//! memory footprint and execution time between the simulated VPA policy
//! and ARC-V; (right) the VPA restart staircase (each OOM restarts the
//! application with a 20 % larger allocation).
//!
//!   cargo bench --bench fig4_footprint_exectime
//!
//! CSVs: bench_out/fig4_ratios.csv, bench_out/fig4_staircase.csv

use arcv::harness::{ratio_row, ratio_table, ratios_csv, run, run_line, ExperimentConfig, PolicyKind};
use arcv::policy::arcv::ArcvParams;
use arcv::util::csv::CsvWriter;
use arcv::util::plot::{bars, multi_line};
use arcv::workloads::{AppId, TABLE1};

fn main() {
    std::fs::create_dir_all("bench_out").ok();
    println!("=== Figure 4 (left): VPA/ARC-V footprint & exec-time ratios ===\n");

    let mut rows = Vec::new();
    for row in &TABLE1 {
        let vpa = run(&ExperimentConfig::vpa_env(row.app), PolicyKind::VpaSim);
        let arcv = run(
            &ExperimentConfig::arcv_env(row.app),
            PolicyKind::ArcvNative(ArcvParams::default()),
        );
        println!("  {}", run_line(&vpa));
        println!("  {}", run_line(&arcv));
        rows.push(ratio_row(&vpa, &arcv, row.exec_secs));
    }
    println!("\n{}", ratio_table(&rows));
    ratios_csv(&rows)
        .save("bench_out/fig4_ratios.csv")
        .expect("write ratios csv");
    println!("wrote bench_out/fig4_ratios.csv\n");

    let fp: Vec<(&str, f64)> = rows
        .iter()
        .map(|r| (r.app.as_str(), r.footprint_ratio))
        .collect();
    print!("{}", bars("footprint ratio VPA/ARC-V (higher = ARC-V saves more)", &fp, 48));
    let et: Vec<(&str, f64)> = rows
        .iter()
        .map(|r| (r.app.as_str(), r.exectime_ratio))
        .collect();
    print!(
        "{}",
        bars("\nexec-time ratio VPA/ARC-V (higher = VPA pays more restarts)", &et, 48)
    );

    // ---- right panel: the restart staircase on a Growth app -----------------
    println!("\n=== Figure 4 (right): VPA restart staircase (CM1) ===\n");
    let r = run(&ExperimentConfig::vpa_env(AppId::Cm1), PolicyKind::VpaSim);
    let usage: Vec<f64> = r.usage_series.iter().map(|&(_, v)| v).collect();
    let limit: Vec<f64> = r.limit_series.iter().map(|&(_, v)| v).collect();
    print!(
        "{}",
        multi_line(
            &format!(
                "CM1 under VPA-sim: usage vs recommendation (GB); {} restarts, wall {}s vs 913s nominal",
                r.restarts, r.wall_secs
            ),
            &[("usage", &usage), ("vpa-rec", &limit)],
            100,
            14,
        )
    );
    let mut csv = CsvWriter::new(&["t_secs", "usage_gb", "recommendation_gb"]);
    for ((t, u), (_, l)) in r.usage_series.iter().zip(r.limit_series.iter()) {
        csv.frow(&[*t as f64, *u, *l]);
    }
    csv.save("bench_out/fig4_staircase.csv").expect("write staircase csv");
    println!("wrote bench_out/fig4_staircase.csv");

    // §5 Overhead check across apps
    println!("\n=== §5 Overhead: ARC-V exec-time delta vs nominal ===");
    for row in rows {
        println!(
            "  {:<12} {:>6.2}% {}",
            row.app,
            row.arcv_overhead_pct,
            if row.arcv_overhead_pct < 3.0 { "(< 3%, as reported)" } else { "(above 3% — swap case)" }
        );
    }
}
