//! Bench: the real-traffic bencher — open-loop saturation curves per
//! kernel mode, plus the trace capture/replay round-trip on a live run.
//!
//!   cargo bench --bench scenario_loadgen
//!
//! Sweeps offered submission rates (open-loop on the sim clock — no
//! coordinated omission) against a 2-node fleet under {lockstep, serial
//! event, sharded} kernels, records per-rate admission-to-running latency
//! p50/p99/p999, and emits `bench_out/BENCH_loadgen.json` with the max
//! sustainable submissions/sec per mode. Then captures the highest
//! unsaturated run to a `$timestamp $json`-lines trace, replays it, and
//! verifies the replayed `EventLog` record-by-record.
//!
//! Emits a machine-readable `BENCH {json}` block. Exits non-zero if:
//!   - a sweep finds no sustainable rate at all (the curve is empty),
//!   - an unsaturated point misses its offered rate beyond tolerance
//!     (the open-loop pacing contract),
//!   - the saturation curve differs between kernel modes,
//!   - the trace round-trip is not the identity, or the replay diverges
//!     from the captured watch stream.

use arcv::harness::SwapKind;
use arcv::loadgen::{mode_label, sweep, SweepConfig, SweepResult, Trace};
use arcv::scenario::{run_scenario_mode, ScenarioPolicy, ScenarioSpec, WorkloadMix};
use arcv::simkube::KernelMode;
use arcv::util::json::{arr, num, obj, s, Json};
use arcv::workloads::AppId;
use std::time::Instant;

/// Relative tolerance for achieved-vs-offered below saturation. The
/// schedule rounds `rate × window` to whole jobs and submit times to
/// whole ticks, so the achieved rate can differ by at most one job over
/// the window; 5 % on top covers the smallest rate in the sweep.
const RATE_TOLERANCE: f64 = 0.05;

fn base_spec() -> ScenarioSpec {
    // two 64 GB workers, short-running mixed load (amr ~253 s / 3.1 GB,
    // sputnipic ~210 s / 10.6 GB at the Fixed policy's 120 % init) — the
    // knee of the curve lands inside the swept rates below
    ScenarioSpec::new("loadgen")
        .pool("w", 2, 64.0, SwapKind::Hdd(32.0))
        .mix(WorkloadMix::uniform(&[AppId::Amr, AppId::Sputnipic]))
        .metrics_history(64)
}

fn sweep_cfg() -> SweepConfig {
    SweepConfig {
        window_secs: 600,
        drain_secs: 2_400,
        rates_per_sec: vec![0.02, 0.04, 0.08, 0.16, 0.32],
        seed: 42,
    }
}

fn point_json(p: &arcv::loadgen::RatePoint) -> Json {
    obj(vec![
        ("offered_per_sec", num(p.offered_per_sec)),
        ("achieved_per_sec", num(p.achieved_per_sec)),
        ("jobs", num(p.jobs as f64)),
        ("completed", num(p.completed as f64)),
        ("stuck_pending", num(p.stuck_pending as f64)),
        ("unfinished", num(p.unfinished as f64)),
        ("dropped", num(p.dropped as f64)),
        ("rejected", num(p.rejected as f64)),
        ("saturated", Json::Bool(p.saturated)),
        ("admission_p50", num(p.admission.p50)),
        ("admission_p99", num(p.admission.p99)),
        ("admission_p999", num(p.admission.p999)),
        ("admission_mean", num(p.admission.mean)),
        ("wall_ticks", num(p.wall_ticks as f64)),
    ])
}

fn sweep_json(r: &SweepResult, secs: f64) -> Json {
    obj(vec![
        ("mode", s(&mode_label(r.mode))),
        (
            "max_sustainable_per_sec",
            r.max_sustainable_per_sec.map(num).unwrap_or(Json::Null),
        ),
        ("wall_secs", num(secs)),
        ("points", arr(r.points.iter().map(point_json).collect())),
    ])
}

fn main() {
    let spec = base_spec();
    let cfg = sweep_cfg();
    let policy = ScenarioPolicy::Fixed;
    let modes = [
        KernelMode::Lockstep,
        KernelMode::EventDriven,
        KernelMode::Sharded { threads: 0 },
    ];

    println!("=== open-loop rate sweep: saturation per kernel mode ===\n");
    let mut sweeps: Vec<(SweepResult, f64)> = Vec::new();
    for mode in modes {
        let t0 = Instant::now();
        let r = sweep(&spec, policy, mode, &cfg);
        let secs = t0.elapsed().as_secs_f64();
        println!(
            "mode {:<9} max sustainable {}/s  ({secs:.2}s wall)",
            mode_label(mode),
            r.max_sustainable_per_sec
                .map(|x| format!("{x:.3}"))
                .unwrap_or_else(|| "none".to_string()),
        );
        for p in &r.points {
            println!(
                "  rate {:>5.3}/s -> {:>3}/{:<3} done, adm p50/p99/p999 \
                 {:>5.0}/{:>5.0}/{:>5.0}s, {}",
                p.offered_per_sec,
                p.completed,
                p.jobs,
                p.admission.p50,
                p.admission.p99,
                p.admission.p999,
                if p.saturated { "SATURATED" } else { "ok" },
            );
        }
        sweeps.push((r, secs));
    }

    // gates over the curves
    let mut no_sustainable = false;
    let mut rate_missed = false;
    for (r, _) in &sweeps {
        if r.max_sustainable_per_sec.is_none() {
            no_sustainable = true;
        }
        for p in &r.points {
            if !p.saturated {
                let rel = (p.achieved_per_sec - p.offered_per_sec).abs() / p.offered_per_sec;
                if rel > RATE_TOLERANCE {
                    rate_missed = true;
                    eprintln!(
                        "offered {} achieved {} (rel err {rel:.3}) in mode {}",
                        p.offered_per_sec,
                        p.achieved_per_sec,
                        mode_label(r.mode),
                    );
                }
            }
        }
    }
    let modes_identical = sweeps
        .iter()
        .all(|(r, _)| r.points == sweeps[0].0.points);
    println!(
        "\nsaturation curves across {} kernel modes: {}",
        sweeps.len(),
        if modes_identical { "bit-identical" } else { "DIVERGED" },
    );

    println!("\n=== trace capture -> parse -> replay on the knee run ===\n");
    // capture the highest unsaturated rate under the event kernel
    let knee_rate = sweeps[0].0.max_sustainable_per_sec.unwrap_or(0.02);
    let knee_jobs = ((knee_rate * cfg.window_secs as f64).round() as usize).max(1);
    let knee_spec = spec
        .clone()
        .arrivals(arcv::scenario::Arrivals::OpenLoop { rate_per_sec: knee_rate })
        .jobs(knee_jobs)
        .max_ticks(cfg.window_secs + cfg.drain_secs);
    let captured = run_scenario_mode(&knee_spec, policy, cfg.seed, KernelMode::EventDriven);
    let trace = Trace::capture(&knee_spec, &policy, cfg.seed, &captured);
    let text = trace.to_lines();
    let parsed = Trace::parse(&text).expect("captured trace must parse");
    let round_trip_ok = parsed == trace;
    let replay_spec = parsed.replay_spec(&knee_spec).expect("replay spec");
    let mut replay_ok = round_trip_ok;
    let mut replay_err = String::new();
    for mode in modes {
        let replayed = run_scenario_mode(&replay_spec, policy, parsed.header.seed, mode);
        if let Err(e) = parsed.verify_replay(&replayed) {
            replay_ok = false;
            replay_err = format!("[{}] {e}", mode_label(mode));
        }
    }
    println!(
        "captured {} jobs / {} watch records ({} bytes); round-trip {}, replay {}",
        trace.header.jobs,
        trace.header.records,
        text.len(),
        if round_trip_ok { "identity" } else { "NOT identity" },
        if replay_ok { "bit-identical in every kernel mode" } else { "DIVERGED" },
    );

    let bench_json = obj(vec![
        ("bench", s("scenario_loadgen")),
        ("window_secs", num(cfg.window_secs as f64)),
        ("drain_secs", num(cfg.drain_secs as f64)),
        ("seed", num(cfg.seed as f64)),
        ("rate_tolerance", num(RATE_TOLERANCE)),
        ("modes_identical", Json::Bool(modes_identical)),
        ("trace_jobs", num(trace.header.jobs as f64)),
        ("trace_records", num(trace.header.records as f64)),
        ("trace_bytes", num(text.len() as f64)),
        ("trace_round_trip", Json::Bool(round_trip_ok)),
        ("replay_identical", Json::Bool(replay_ok)),
        (
            "modes",
            arr(sweeps.iter().map(|(r, secs)| sweep_json(r, *secs)).collect()),
        ),
    ]);
    std::fs::create_dir_all("bench_out").ok();
    std::fs::write("bench_out/BENCH_loadgen.json", bench_json.to_string_pretty())
        .expect("write bench_out/BENCH_loadgen.json");
    println!("\nwrote bench_out/BENCH_loadgen.json");
    println!("\nBENCH {}", bench_json.to_string_pretty());

    if no_sustainable {
        eprintln!("FAIL: a sweep found no sustainable rate (curve is empty)");
        std::process::exit(1);
    }
    if rate_missed {
        eprintln!("FAIL: offered rate not achieved within tolerance below saturation");
        std::process::exit(1);
    }
    if !modes_identical {
        eprintln!("FAIL: saturation curve differs between kernel modes");
        std::process::exit(1);
    }
    if !round_trip_ok {
        eprintln!("FAIL: trace capture -> serialize -> parse is not the identity");
        std::process::exit(1);
    }
    if !replay_ok {
        eprintln!("FAIL: trace replay diverged from the captured run: {replay_err}");
        std::process::exit(1);
    }
}
