//! Bench: regenerate **Figure 5** — ARC-V's memory-limit decisions against
//! live usage for the three state-dominated showcases: Kripke (Growing),
//! LAMMPS (Stable), LULESH (Dynamic). As in the paper, the starting limits
//! are exaggerated for display.
//!
//!   cargo bench --bench fig5_decisions
//!
//! CSVs: bench_out/fig5_<app>.csv

use arcv::harness::{run, run_line, ExperimentConfig, PolicyKind};
use arcv::policy::arcv::ArcvParams;
use arcv::util::csv::CsvWriter;
use arcv::util::plot::multi_line;
use arcv::workloads::AppId;

fn main() {
    std::fs::create_dir_all("bench_out").ok();
    println!("=== Figure 5: ARC-V limit decisions per dominant state ===");
    // (app, exaggerated initial fraction of max — per the paper's caption)
    let scenes = [
        (AppId::Kripke, 1.2, "Growing-dominated"),
        (AppId::Lammps, 8.0, "Stable-dominated"),
        (AppId::Lulesh, 4.0, "Dynamic-dominated"),
    ];
    for (app, init_frac, label) in scenes {
        let mut cfg = ExperimentConfig::arcv_env(app);
        cfg.initial_frac = init_frac;
        let r = run(&cfg, PolicyKind::ArcvNative(ArcvParams::default()));
        println!("\n  {}", run_line(&r));
        let usage: Vec<f64> = r.usage_series.iter().map(|&(_, v)| v).collect();
        let limit: Vec<f64> = r.limit_series.iter().map(|&(_, v)| v).collect();
        print!(
            "{}",
            multi_line(
                &format!("{} ({label}) — usage vs ARC-V limit (GB)", app),
                &[("usage", &usage), ("arcv-limit", &limit)],
                100,
                14,
            )
        );
        let mut csv = CsvWriter::new(&["t_secs", "usage_gb", "arcv_limit_gb", "swap_gb"]);
        for ((t, u), ((_, l), (_, s))) in r
            .usage_series
            .iter()
            .zip(r.limit_series.iter().zip(r.swap_series.iter()))
        {
            csv.frow(&[*t as f64, *u, *l, *s]);
        }
        let path = format!("bench_out/fig5_{}.csv", app.name());
        csv.save(&path).expect("write fig5 csv");
        println!("wrote {path}");

        // Paper's §5 Kripke observation: rec drops from 6.6GB toward 5.6GB
        // by about a third of the execution.
        if app == AppId::Kripke {
            let third = r.limit_series.len() / 3;
            let lim_at_third = r.limit_series[third].1;
            println!(
                "  Kripke limit at 1/3 of execution: {:.2} GB (paper: ~5.6 GB from 6.6 GB)",
                lim_at_third
            );
        }
    }
}
