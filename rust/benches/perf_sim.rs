//! Perf bench: simulator + controller throughput (ticks/second) — the L3
//! numbers for EXPERIMENTS.md §Perf. The controller must be a negligible
//! fraction of the tick budget (the paper's <3 % overhead claim is about
//! the real cluster; here we check our own coordinator cost).
//!
//!   cargo bench --bench perf_sim

use arcv::coordinator::controller::{Controller, Tick};
use arcv::coordinator::fleet::FleetController;
use arcv::policy::arcv::{ArcvParams, ArcvPolicy, NativeFleet};
use arcv::simkube::cluster::Cluster;
use arcv::simkube::node::Node;
use arcv::simkube::resources::ResourceSpec;
use arcv::simkube::swap::SwapDevice;
use arcv::util::bench::bench;
use arcv::workloads::{build, AppId};

fn cluster_with_pods(n_pods: usize) -> (Cluster, Vec<usize>) {
    let mut c = Cluster::new(
        (0..((n_pods + 15) / 16).max(1))
            .map(|i| Node::new(&format!("w{i}"), 1024.0, SwapDevice::hdd(256.0)))
            .collect(),
        Default::default(),
    );
    let apps = AppId::all();
    let ids = (0..n_pods)
        .map(|i| {
            let m = build(apps[i % apps.len()], i as u64);
            let init = m.max_gb * 1.2;
            c.create_pod(&format!("p{i}"), ResourceSpec::memory_exact(init), Box::new(m))
        })
        .collect();
    (c, ids)
}

fn main() {
    println!("=== bare simulator throughput (no controller) ===");
    for n in [1usize, 4, 16, 64] {
        let (mut c, _) = cluster_with_pods(n);
        let r = bench(&format!("sim/step pods={n}"), 50, 2000, || c.step());
        println!(
            "    -> {:.2} M pod-ticks/s",
            r.per_sec(n as f64) / 1e6
        );
    }

    println!("\n=== simulator + per-pod ARC-V controller ===");
    for n in [1usize, 4, 16, 64] {
        let (mut c, ids) = cluster_with_pods(n);
        let mut ctl = Controller::new();
        for &id in &ids {
            let init = c.pod(id).effective_limit_gb;
            ctl.manage(id, Box::new(ArcvPolicy::new(init, ArcvParams::default())));
        }
        bench(&format!("sim+arcv/step pods={n}"), 50, 2000, || {
            c.step();
            ctl.tick(&mut c);
        });
    }

    println!("\n=== simulator + fleet controller (native backend) ===");
    for n in [1usize, 4, 16, 64] {
        let (mut c, ids) = cluster_with_pods(n);
        let params = ArcvParams::default();
        let mut ctl = FleetController::from_backend(Box::new(NativeFleet::new(64, params.window)), params);
        for &id in &ids {
            let init = c.pod(id).effective_limit_gb;
            ctl.manage(id, init);
        }
        bench(&format!("sim+fleet/step pods={n}"), 50, 2000, || {
            c.step();
            ctl.tick(&mut c);
        });
    }

    println!("\n=== end-to-end experiment wall time (kripke, 650 sim-seconds) ===");
    use arcv::harness::{run, ExperimentConfig, PolicyKind};
    let r = bench("e2e/kripke+arcv full run", 1, 12, || {
        run(
            &ExperimentConfig::arcv_env(AppId::Kripke),
            PolicyKind::ArcvNative(ArcvParams::default()),
        )
    });
    println!(
        "    -> {:.0} sim-seconds/wall-second",
        650.0 / (r.mean_ns * 1e-9)
    );
}
