//! Perf bench: simulator + controller throughput (ticks/second) — the L3
//! numbers for EXPERIMENTS.md §Perf. The controller must be a negligible
//! fraction of the tick budget (the paper's <3 % overhead claim is about
//! the real cluster; here we check our own coordinator cost).
//!
//! Emits four json artifacts under `bench_out/`: BENCH_kernel (event
//! kernel vs the 1 s-stepping reference over the Fig 4 sweep),
//! BENCH_informer (delta replay vs relist per wake + the subscription
//! scrape plane), BENCH_decide (the decision plane: scalar per-pod
//! loop vs the SoA batch, serial and parallel, at 1k/10k/50k managed
//! pods — gated so the batch is never slower than the scalar loop and
//! the parallel batch never slower than the serial one), and
//! BENCH_shardlog (the sharded event-log control plane: unified
//! single-shard log vs the 8-way sharded layout at 1k/10k/100k pods,
//! per-wake informer sync plus a resize-storm stepping-region phase —
//! gated so the sharded layout is never slower and the event-stream
//! FNV fingerprint is identical across shard counts).
//!
//!   cargo bench --bench perf_sim

use arcv::coordinator::controller::{Controller, DecidePlane, Tick};
use arcv::coordinator::fleet::FleetController;
use arcv::harness::{run_with_mode, ExperimentConfig, PolicyKind, RunOutput};
use arcv::policy::arcv::{ArcvParams, ArcvPolicy, NativeFleet};
use arcv::simkube::cluster::Cluster;
use arcv::simkube::node::Node;
use arcv::simkube::resources::ResourceSpec;
use arcv::simkube::swap::SwapDevice;
use arcv::simkube::{AdvanceOpts, ApiClient, Event, KernelMode, ScrapeCadence, SubscriptionSet};
use arcv::util::bench::bench;
use arcv::util::json::{arr, num, obj, s, Json};
use arcv::workloads::{build, AppId};
use std::time::Instant;

const POLICY_NAMES: [&str; 4] = ["arcv", "vpa-sim", "fixed", "oracle"];

/// One (app, policy-environment) run — the Fig 4 sweep grid, matching
/// `rust/tests/kernel_equivalence.rs`.
fn sweep_case(app: AppId, i: usize, mode: KernelMode) -> RunOutput {
    let (cfg, kind) = match i {
        0 => (
            ExperimentConfig::arcv_env(app),
            PolicyKind::ArcvNative(ArcvParams::default()),
        ),
        1 => (ExperimentConfig::vpa_env(app), PolicyKind::VpaSim),
        2 => (ExperimentConfig::arcv_env(app), PolicyKind::Fixed),
        _ => (ExperimentConfig::arcv_env(app), PolicyKind::Oracle),
    };
    run_with_mode(&cfg, kind, mode)
}

/// Best-of-2 wall time for one case under `mode` (runs are deterministic;
/// the second sample shakes out cold caches), plus the run itself.
fn timed(app: AppId, i: usize, mode: KernelMode) -> (f64, RunOutput) {
    let t0 = Instant::now();
    let first = sweep_case(app, i, mode);
    let ns_a = t0.elapsed().as_nanos() as f64;
    let t0 = Instant::now();
    let second = sweep_case(app, i, mode);
    let ns_b = t0.elapsed().as_nanos() as f64;
    drop(first);
    (ns_a.min(ns_b), second)
}

fn cluster_with_pods(n_pods: usize) -> (Cluster, Vec<usize>) {
    let mut c = Cluster::new(
        (0..((n_pods + 15) / 16).max(1))
            .map(|i| Node::new(&format!("w{i}"), 1024.0, SwapDevice::hdd(256.0)))
            .collect(),
        Default::default(),
    );
    let apps = AppId::all();
    let ids = (0..n_pods)
        .map(|i| {
            let m = build(apps[i % apps.len()], i as u64);
            let init = m.max_gb * 1.2;
            c.create_pod(&format!("p{i}"), ResourceSpec::memory_exact(init), Box::new(m))
        })
        .collect();
    (c, ids)
}

/// One decision-plane bench run: `n` ARC-V-managed pods driven at the
/// controller's declared wake cadence until the sampling windows have
/// filled and several full-fleet decision passes have run, with the
/// plane and worker count forced. Returns the controller's own
/// decide-pass telemetry plus the full event log — the bit-identity
/// tripwire across planes.
struct DecideCell {
    secs: f64,
    passes: u64,
    workers: usize,
    events: Vec<Event>,
}

fn decide_cell(n: usize, plane: DecidePlane, threads: usize) -> DecideCell {
    let (mut c, ids) = cluster_with_pods(n);
    let mut ctl = Controller::new();
    for &id in &ids {
        let init = c.pod(id).effective_limit_gb;
        ctl.manage(id, Box::new(ArcvPolicy::new(init, ArcvParams::default())));
    }
    ctl.set_decide_plane(plane);
    ctl.policy_mut().set_decide_threads(threads);
    // enough horizon for every pod's sampling window to fill plus
    // several full-fleet decision intervals
    let horizon = c.metrics.period_secs * 12 + 5 * 60;
    // mirror the kernel loop: keep the cluster's sampler aligned with the
    // declared interest set and wake the controller only at its cadence
    let mut sub_rev: Option<u64> = None;
    while c.now < horizon {
        if let Some(subs) = ctl.subscriptions() {
            if sub_rev != Some(subs.revision()) {
                sub_rev = Some(subs.revision());
                c.install_subscriptions(subs.clone());
            }
        }
        let wake = ctl.next_wake(&c).min(horizon);
        while c.now < wake {
            c.step();
        }
        ctl.tick(&mut c);
    }
    let coast = ctl.coast().unwrap_or_default();
    DecideCell {
        secs: coast.decide_nanos as f64 / 1e9,
        passes: coast.decide_passes,
        workers: ctl.policy().last_decide_workers(),
        events: c.events.into_snapshot(),
    }
}

/// `cluster_with_pods`, but with the event log laid out over `k` watch
/// shards — `set_event_shards` requires a virgin log, so the layout is
/// installed before the first `create_pod` record. `k = 1` is the
/// unified single-log baseline.
fn shardlog_cluster(n_pods: usize, k: usize) -> Cluster {
    let n_nodes = ((n_pods + 15) / 16).max(1);
    let mut c = Cluster::new(
        (0..n_nodes)
            .map(|i| Node::new(&format!("w{i}"), 1024.0, SwapDevice::hdd(256.0)))
            .collect(),
        Default::default(),
    );
    let k = k.min(n_nodes).max(1);
    c.set_event_shards((0..n_nodes).map(|node| node * k / n_nodes).collect());
    let apps = AppId::all();
    for i in 0..n_pods {
        let m = build(apps[i % apps.len()], i as u64);
        let init = m.max_gb * 1.2;
        c.create_pod(&format!("p{i}"), ResourceSpec::memory_exact(init), Box::new(m));
    }
    c
}

/// FNV-1a over the debug rendering of every retained event — the
/// cross-layout fingerprint BENCH_shardlog records per shard count
/// (same algorithm as `rust/tests/kernel_equivalence.rs`).
fn event_stream_hash(events: &[Event]) -> u64 {
    use std::fmt::Write as _;
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut line = String::new();
    for e in events {
        line.clear();
        let _ = write!(line, "{e:?}");
        for &b in line.as_bytes() {
            h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
        }
        h = (h ^ 0x0a).wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// One unified-vs-sharded log cell: the same deterministic workload over
/// a `k`-shard store, timed in two phases. Phase 1 is the informer path
/// (per-wake delta sync over the sharded watch plane, with the same
/// patch trickle as the informer gate). Phase 2 is the region path: a
/// resize storm keeps `pending_resize` set on a rotating eighth of the
/// fleet, so `advance_to` runs hot stepping regions whose workers append
/// straight into their node's shard (k > 1) or hand cell buffers to the
/// coordinator's serial merge (k = 1) — `merge_nanos` is that
/// coordinator cost. The final event stream must be bit-identical across
/// layouts; the hash and revision come back for the gate.
struct ShardlogCell {
    shards: usize,
    sync_secs: f64,
    region_secs: f64,
    merge_nanos: u64,
    regions_entered: u64,
    hash: u64,
    revision: u64,
}

fn shardlog_cell(n: usize, k: usize, threads: usize) -> ShardlogCell {
    let mut c = shardlog_cluster(n, k);
    let shards = c.events.shard_count();
    let mut client = ApiClient::new();
    client.sync(&mut c); // the initial LIST, paid once by every layout
    let wakes = if n >= 100_000 { 60u64 } else { 200 };
    let mut sync_ns = 0.0f64;
    let mut next_patch = 0usize;
    for w in 0..wakes {
        c.step();
        if w % 4 == 0 {
            let id = next_patch % n;
            next_patch += 7;
            let lim = c.pod(id).effective_limit_gb;
            if lim.is_finite() {
                c.patch_pod_memory(id, lim);
            }
        }
        let t0 = Instant::now();
        let _delta = client.sync(&mut c);
        sync_ns += t0.elapsed().as_nanos() as f64;
    }
    // clear the scrape ceiling so regions run to their proof ceiling, not
    // to the next full-fleet sampling tick (same as the scenario_fleet
    // thrash rung) — identical on both layouts, so equivalence holds
    c.install_subscriptions(SubscriptionSet::new());
    let opts = AdvanceOpts { event_driven: true, sample_metrics: true, shards: threads };
    let batch = (n / 8).max(1);
    let spans = if n >= 100_000 { 10u64 } else { 30 };
    let mut next = 0usize;
    let t0 = Instant::now();
    for _ in 0..spans {
        for _ in 0..batch {
            let id = next % n;
            next += 1;
            let lim = c.pod(id).effective_limit_gb;
            if lim.is_finite() {
                c.patch_pod_memory(id, lim);
            }
        }
        // an 8-tick span: wide enough to clear the window >= 2 floor, hot
        // enough (the fresh `pending_resize` batch) to force step_region
        let end = c.now + 8;
        while c.now < end {
            c.advance_to(end, opts);
        }
    }
    let region_secs = t0.elapsed().as_secs_f64();
    let revision = c.events.revision();
    ShardlogCell {
        shards,
        sync_secs: sync_ns * 1e-9,
        region_secs,
        merge_nanos: c.coast_stats.merge_nanos,
        regions_entered: c.coast_stats.regions_entered,
        hash: event_stream_hash(&c.events.snapshot()),
        revision,
    }
}

fn main() {
    println!("=== bare simulator throughput (no controller) ===");
    for n in [1usize, 4, 16, 64] {
        let (mut c, _) = cluster_with_pods(n);
        let r = bench(&format!("sim/step pods={n}"), 50, 2000, || c.step());
        println!(
            "    -> {:.2} M pod-ticks/s",
            r.per_sec(n as f64) / 1e6
        );
    }

    println!("\n=== simulator + per-pod ARC-V controller ===");
    for n in [1usize, 4, 16, 64] {
        let (mut c, ids) = cluster_with_pods(n);
        let mut ctl = Controller::new();
        for &id in &ids {
            let init = c.pod(id).effective_limit_gb;
            ctl.manage(id, Box::new(ArcvPolicy::new(init, ArcvParams::default())));
        }
        bench(&format!("sim+arcv/step pods={n}"), 50, 2000, || {
            c.step();
            ctl.tick(&mut c);
        });
    }

    println!("\n=== simulator + fleet controller (native backend) ===");
    for n in [1usize, 4, 16, 64] {
        let (mut c, ids) = cluster_with_pods(n);
        let params = ArcvParams::default();
        let mut ctl = FleetController::from_backend(Box::new(NativeFleet::new(64, params.window)), params);
        for &id in &ids {
            let init = c.pod(id).effective_limit_gb;
            ctl.manage(id, init);
        }
        bench(&format!("sim+fleet/step pods={n}"), 50, 2000, || {
            c.step();
            ctl.tick(&mut c);
        });
    }

    println!("\n=== end-to-end experiment wall time (kripke, 650 sim-seconds) ===");
    use arcv::harness::run;
    let r = bench("e2e/kripke+arcv full run", 1, 12, || {
        run(
            &ExperimentConfig::arcv_env(AppId::Kripke),
            PolicyKind::ArcvNative(ArcvParams::default()),
        )
    });
    println!(
        "    -> {:.0} sim-seconds/wall-second",
        650.0 / (r.mean_ns * 1e-9)
    );

    // ---- the kernel gate: event-driven clock vs per-second loop ------------
    println!("\n=== discrete-event kernel vs 1 s-stepping reference: Fig 4 app sweep ===\n");
    let mut rows = Vec::new();
    let mut lock_ns_total = 0.0_f64;
    let mut event_ns_total = 0.0_f64;
    let mut sim_ticks_total = 0u64;
    let mut kernel_events_total = 0u64;
    let mut mismatches = 0usize;
    let mut sharded_ns_total = 0.0_f64;
    for app in AppId::all() {
        for i in 0..POLICY_NAMES.len() {
            let (lock_ns, reference) = timed(app, i, KernelMode::Lockstep);
            let (event_ns, event) = timed(app, i, KernelMode::EventDriven);
            let (sharded_ns, sharded) = timed(app, i, KernelMode::Sharded { threads: 0 });
            // the full equivalence proof lives in
            // rust/tests/kernel_equivalence.rs; this is the bench's own
            // cheap tripwire so a perf number never ships off a wrong sim
            let identical =
                reference.result == event.result && reference.result == sharded.result;
            if !identical {
                mismatches += 1;
                eprintln!("MISMATCH: {app}/{} diverged between kernels", POLICY_NAMES[i]);
            }
            let case_speedup = lock_ns / event_ns.max(1.0);
            println!(
                "  {:<10} {:<8} {:>8} ticks  lockstep {:>9.3} ms  event {:>9.3} ms  sharded {:>9.3} ms  ({:>5.1}x, {} events)",
                app.name(),
                POLICY_NAMES[i],
                event.stats.sim_ticks,
                lock_ns / 1e6,
                event_ns / 1e6,
                sharded_ns / 1e6,
                case_speedup,
                event.stats.events,
            );
            lock_ns_total += lock_ns;
            event_ns_total += event_ns;
            sharded_ns_total += sharded_ns;
            sim_ticks_total += event.stats.sim_ticks;
            kernel_events_total += event.stats.events;
            rows.push(obj(vec![
                ("app", s(app.name())),
                ("policy", s(POLICY_NAMES[i])),
                ("sim_ticks", num(event.stats.sim_ticks as f64)),
                ("kernel_events", num(event.stats.events as f64)),
                ("ctl_wakes", num(event.stats.ctl_wakes as f64)),
                ("lockstep_ms", num(lock_ns / 1e6)),
                ("event_ms", num(event_ns / 1e6)),
                ("sharded_ms", num(sharded_ns / 1e6)),
                ("speedup", num(case_speedup)),
                ("identical", Json::Bool(identical)),
            ]));
        }
    }
    let speedup = lock_ns_total / event_ns_total.max(1.0);
    let ticks_per_sec_lockstep = sim_ticks_total as f64 / (lock_ns_total * 1e-9).max(1e-12);
    let ticks_per_sec_event = sim_ticks_total as f64 / (event_ns_total * 1e-9).max(1e-12);
    let events_per_sec = kernel_events_total as f64 / (event_ns_total * 1e-9).max(1e-12);
    println!(
        "\nsweep total: lockstep {:.1} ms, event {:.1} ms -> {:.2}x speedup \
         ({:.2} M ticks/s lockstep vs {:.2} M ticks/s event, {:.2} M events/s)",
        lock_ns_total / 1e6,
        event_ns_total / 1e6,
        speedup,
        ticks_per_sec_lockstep / 1e6,
        ticks_per_sec_event / 1e6,
        events_per_sec / 1e6,
    );

    let bench_json = obj(vec![
        ("bench", s("perf_sim/kernel")),
        ("apps", num(AppId::all().len() as f64)),
        ("policies", num(POLICY_NAMES.len() as f64)),
        ("sim_ticks", num(sim_ticks_total as f64)),
        ("kernel_events", num(kernel_events_total as f64)),
        ("lockstep_secs", num(lock_ns_total * 1e-9)),
        ("event_secs", num(event_ns_total * 1e-9)),
        ("sharded_secs", num(sharded_ns_total * 1e-9)),
        ("speedup", num(speedup)),
        ("ticks_per_sec_lockstep", num(ticks_per_sec_lockstep)),
        ("ticks_per_sec_event", num(ticks_per_sec_event)),
        ("events_per_sec", num(events_per_sec)),
        ("mismatches", num(mismatches as f64)),
        ("rows", arr(rows)),
    ]);
    std::fs::create_dir_all("bench_out").ok();
    std::fs::write("bench_out/BENCH_kernel.json", bench_json.to_string_pretty())
        .expect("write bench_out/BENCH_kernel.json");
    println!("\nBENCH {}", bench_json.to_string_pretty());
    println!("wrote bench_out/BENCH_kernel.json");

    // ---- the informer gate: delta replay vs full relist per wake -----------
    // Two informers over one live cluster, synced back to back every wake:
    // the delta informer replays watch records, the relist oracle rebuilds
    // every view (the pre-PR 5 cost). A trickle of patches keeps the delta
    // path honest (non-empty tails), and auto-compaction runs live to show
    // the cursor-pinned log staying bounded.
    println!("\n=== informer: delta replay vs full relist, per controller wake ===\n");
    let mut informer_rows = Vec::new();
    let mut informer_slow = false;
    for n in [1_000usize, 10_000, 50_000] {
        let (mut c, _ids) = cluster_with_pods(n);
        c.events.set_auto_compact(true);
        let mut delta_client = ApiClient::new();
        let mut relist_client = ApiClient::new();
        // the initial LIST is paid once by both; not part of the per-wake cost
        delta_client.sync(&mut c);
        relist_client.sync_relist(&mut c);
        let wakes = 200u64;
        let mut delta_ns = 0.0f64;
        let mut relist_ns = 0.0f64;
        let mut next_patch = 0usize;
        for w in 0..wakes {
            c.step();
            if w % 4 == 0 {
                // churn trickle: re-apply one pod's current spec limit (a
                // real ResizeIssued record, no behavioural change)
                let id = next_patch % n;
                next_patch += 7;
                let lim = c.pod(id).effective_limit_gb;
                if lim.is_finite() {
                    c.patch_pod_memory(id, lim);
                }
            }
            let t0 = Instant::now();
            let _delta = delta_client.sync(&mut c);
            delta_ns += t0.elapsed().as_nanos() as f64;
            let t0 = Instant::now();
            let _full = relist_client.sync_relist(&mut c);
            relist_ns += t0.elapsed().as_nanos() as f64;
        }
        let dstats = delta_client.informer_stats();
        let rstats = relist_client.informer_stats();
        let delta_us = delta_ns / wakes as f64 / 1e3;
        let relist_us = relist_ns / wakes as f64 / 1e3;
        let speedup = relist_ns / delta_ns.max(1.0);
        // the gate: delta replay must never be slower than relisting
        // (5 % tolerance for shared-runner noise)
        if delta_ns > relist_ns * 1.05 {
            informer_slow = true;
        }
        let retained = c.events.retained_len() as u64;
        let total = c.events.revision();
        println!(
            "  {n:>6} pods: delta {delta_us:>9.2} us/wake ({} views rebuilt over {wakes} wakes) \
             vs relist {relist_us:>9.2} us/wake ({} rebuilt) -> {speedup:>6.1}x; \
             log retained {retained}/{total} records",
            dstats.views_rebuilt, rstats.views_rebuilt,
        );
        assert_eq!(dstats.relists, 1, "delta informer must never relist after the LIST");
        assert!(
            retained < total || total < 128,
            "cursor-pinned auto-compaction must bound the log ({retained}/{total})"
        );
        informer_rows.push(obj(vec![
            ("pods", num(n as f64)),
            ("wakes", num(wakes as f64)),
            ("delta_us_per_wake", num(delta_us)),
            ("relist_us_per_wake", num(relist_us)),
            ("speedup", num(speedup)),
            ("delta_views_rebuilt", num(dstats.views_rebuilt as f64)),
            ("relist_views_rebuilt", num(rstats.views_rebuilt as f64)),
            ("delta_relists", num(dstats.relists as f64)),
            ("events_replayed", num(dstats.events_replayed as f64)),
            ("log_retained", num(retained as f64)),
            ("log_revision", num(total as f64)),
        ]));
    }
    // ---- the scrape gate: subscription sampling vs the full-fleet pass ----
    // Per-wake cost of one scrape pass as the subscribed fraction grows:
    // the subscription sampler walks only its interest set, the legacy
    // discipline (cleared subscriptions) walks every pod. The pass is
    // timed directly (`Cluster::scrape_now`) so simulator stepping cost
    // cannot mask the difference.
    println!("\n=== scrape plane: subscription sampling vs full-fleet pass, per wake ===\n");
    let mut scrape_rows = Vec::new();
    let mut scrape_slow = false;
    let mut scrape_sparse_fast = true;
    for n in [10_000usize, 50_000] {
        let (mut c, ids) = cluster_with_pods(n);
        // settle on a grid-aligned tick so Grid cadences are due and the
        // fleet has scheduled
        for _ in 0..c.metrics.period_secs * 2 {
            c.step();
        }
        let wakes = 200u32;
        c.clear_subscriptions();
        let t0 = Instant::now();
        for _ in 0..wakes {
            c.scrape_now();
        }
        let full_us = t0.elapsed().as_nanos() as f64 / wakes as f64 / 1e3;
        for frac in [0.0f64, 0.01, 0.1, 1.0] {
            let take = ((n as f64 * frac).round() as usize).min(n);
            let mut subs = SubscriptionSet::new();
            for &id in ids.iter().take(take) {
                subs.subscribe(id, ScrapeCadence::Grid);
            }
            c.install_subscriptions(subs);
            let t0 = Instant::now();
            for _ in 0..wakes {
                c.scrape_now();
            }
            let sub_us = t0.elapsed().as_nanos() as f64 / wakes as f64 / 1e3;
            let speedup = full_us / sub_us.max(1e-9);
            // gates: subscribed sampling must never cost more than the
            // full pass it replaces (5 % tolerance for runner noise), and
            // a 1 % subscription must be measurably below the full pass
            if sub_us > full_us * 1.05 {
                scrape_slow = true;
            }
            if frac == 0.01 && sub_us > full_us * 0.5 {
                scrape_sparse_fast = false;
            }
            println!(
                "  {n:>6} pods @ {:>5.1}% subscribed ({take:>6}): {sub_us:>9.2} us/wake \
                 vs full pass {full_us:>9.2} us/wake -> {speedup:>7.1}x",
                frac * 100.0,
            );
            scrape_rows.push(obj(vec![
                ("pods", num(n as f64)),
                ("frac", num(frac)),
                ("subscribed", num(take as f64)),
                ("sub_us_per_wake", num(sub_us)),
                ("full_us_per_wake", num(full_us)),
                ("speedup", num(speedup)),
            ]));
        }
    }

    // ---- the decision-plane gate: scalar loop vs SoA batch per wake --------
    // Three controllers over identical fleets, each driven at its declared
    // wake cadence: the legacy scalar plane, the batched plane pinned to
    // one worker, and the batched plane with auto worker selection. The
    // measurement is the controller's own decide telemetry — wall time
    // inside the decide entry point — so informer sync and action
    // submission can't mask the difference. All three event logs must be
    // bit-identical: the planes are behaviourally one (the full proof is
    // rust/tests/kernel_equivalence.rs; this is the bench's tripwire).
    println!("\n=== decision plane: scalar loop vs SoA batch vs parallel batch, per decide pass ===\n");
    let mut decide_rows = Vec::new();
    let mut decide_batched_slow = false;
    let mut decide_parallel_slow = false;
    let mut decide_diverged = false;
    for n in [1_000usize, 10_000, 50_000] {
        let scalar = decide_cell(n, DecidePlane::Scalar, 0);
        let serial = decide_cell(n, DecidePlane::Batched, 1);
        let auto = decide_cell(n, DecidePlane::Batched, 0);
        let identical = scalar.events == serial.events
            && scalar.events == auto.events
            && scalar.passes == serial.passes
            && scalar.passes == auto.passes;
        if !identical {
            decide_diverged = true;
            eprintln!("MISMATCH: decide planes diverged at {n} pods");
        }
        // gates: the batch plane must never lose to the scalar loop, and
        // auto worker selection must never lose to the pinned-serial
        // batch (10 % + 2 ms slack for shared-runner noise; below the
        // parallel threshold auto IS serial, so the second gate is a
        // pure no-regression tripwire there)
        if serial.secs > scalar.secs * 1.10 + 2e-3 {
            decide_batched_slow = true;
        }
        if auto.secs > serial.secs * 1.10 + 2e-3 {
            decide_parallel_slow = true;
        }
        let per_pass_ms = |cell: &DecideCell| cell.secs / cell.passes.max(1) as f64 * 1e3;
        println!(
            "  {n:>6} pods, {} passes: scalar {:>8.3} ms/pass  batched {:>8.3} ms/pass \
             ({:.2}x)  parallel {:>8.3} ms/pass ({:.2}x vs serial batch, {} workers) {}",
            scalar.passes,
            per_pass_ms(&scalar),
            per_pass_ms(&serial),
            scalar.secs / serial.secs.max(1e-12),
            per_pass_ms(&auto),
            serial.secs / auto.secs.max(1e-12),
            auto.workers,
            if identical { "bit-identical" } else { "DIVERGED" },
        );
        decide_rows.push(obj(vec![
            ("pods", num(n as f64)),
            ("decide_passes", num(scalar.passes as f64)),
            ("scalar_secs", num(scalar.secs)),
            ("batched_serial_secs", num(serial.secs)),
            ("batched_parallel_secs", num(auto.secs)),
            ("batched_speedup_vs_scalar", num(scalar.secs / serial.secs.max(1e-12))),
            ("parallel_speedup_vs_serial_batch", num(serial.secs / auto.secs.max(1e-12))),
            ("parallel_workers", num(auto.workers as f64)),
            ("identical", Json::Bool(identical)),
        ]));
    }
    let decide_json = obj(vec![
        ("bench", s("perf_sim/decide")),
        (
            "threads",
            num(std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1) as f64),
        ),
        ("rows", arr(decide_rows)),
        ("batched_never_slower", Json::Bool(!decide_batched_slow)),
        ("parallel_never_slower", Json::Bool(!decide_parallel_slow)),
        ("planes_identical", Json::Bool(!decide_diverged)),
    ]);
    std::fs::write("bench_out/BENCH_decide.json", decide_json.to_string_pretty())
        .expect("write bench_out/BENCH_decide.json");
    println!("\nBENCH {}", decide_json.to_string_pretty());
    println!("wrote bench_out/BENCH_decide.json");

    // ---- the shard-log gate: unified vs sharded watch plane ----------------
    // The same deterministic workload over a 1-shard (unified, the pre-PR
    // layout) and an 8-shard event store: per-wake informer sync over
    // vector cursors, then a resize-storm region phase where workers
    // append into their own shard instead of handing buffers to the
    // coordinator's serial merge. The sharded layout must never be slower
    // and the merged event stream must be bit-identical (same FNV
    // fingerprint, same head revision) — sharding is a layout change, not
    // a behavioural one.
    println!("\n=== event log: unified vs sharded watch plane, sync + region merge ===\n");
    let shardlog_threads =
        std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1);
    let mut shardlog_rows = Vec::new();
    let mut shardlog_sync_slow = false;
    let mut shardlog_region_slow = false;
    let mut shardlog_hash_diverged = false;
    let mut shardlog_merge_nanos = (0u64, 0u64); // (unified, sharded) at the top rung
    for n in [1_000usize, 10_000, 100_000] {
        let unified = shardlog_cell(n, 1, shardlog_threads);
        let sharded = shardlog_cell(n, 8, shardlog_threads);
        let identical =
            unified.hash == sharded.hash && unified.revision == sharded.revision;
        if !identical {
            shardlog_hash_diverged = true;
            eprintln!("MISMATCH: event stream diverged between 1 and {} shards at {n} pods", sharded.shards);
        }
        // gates: the sharded layout must never lose to the unified log on
        // either path (10 % + 2 ms slack for shared-runner noise)
        if sharded.sync_secs > unified.sync_secs * 1.10 + 2e-3 {
            shardlog_sync_slow = true;
        }
        if sharded.region_secs > unified.region_secs * 1.10 + 2e-3 {
            shardlog_region_slow = true;
        }
        shardlog_merge_nanos = (unified.merge_nanos, sharded.merge_nanos);
        println!(
            "  {n:>6} pods: sync unified {:>8.3} ms  sharded({}) {:>8.3} ms ({:.2}x)  \
             regions unified {:>8.3} ms (merge {:>7.3} ms)  sharded {:>8.3} ms (merge \
             {:>7.3} ms)  {}",
            unified.sync_secs * 1e3,
            sharded.shards,
            sharded.sync_secs * 1e3,
            unified.sync_secs / sharded.sync_secs.max(1e-12),
            unified.region_secs * 1e3,
            unified.merge_nanos as f64 / 1e6,
            sharded.region_secs * 1e3,
            sharded.merge_nanos as f64 / 1e6,
            if identical { "bit-identical" } else { "DIVERGED" },
        );
        assert!(
            unified.regions_entered > 0 && sharded.regions_entered > 0,
            "the resize storm must actually drive stepping regions"
        );
        shardlog_rows.push(obj(vec![
            ("pods", num(n as f64)),
            ("shards", num(sharded.shards as f64)),
            ("unified_sync_secs", num(unified.sync_secs)),
            ("sharded_sync_secs", num(sharded.sync_secs)),
            ("sync_speedup", num(unified.sync_secs / sharded.sync_secs.max(1e-12))),
            ("unified_region_secs", num(unified.region_secs)),
            ("sharded_region_secs", num(sharded.region_secs)),
            ("region_speedup", num(unified.region_secs / sharded.region_secs.max(1e-12))),
            ("unified_merge_nanos", num(unified.merge_nanos as f64)),
            ("sharded_merge_nanos", num(sharded.merge_nanos as f64)),
            ("regions_entered", num(sharded.regions_entered as f64)),
            ("event_log_hash", s(&format!("{:016x}", sharded.hash))),
            ("revision", num(sharded.revision as f64)),
            ("identical", Json::Bool(identical)),
        ]));
    }
    // the merge claim at the thrash rung (100k pods): with k > 1 shards
    // region workers flush straight into their shard before the barrier,
    // so the coordinator's post-barrier merge must shrink (25 % + 2 ms
    // slack — the json carries the raw nanos either way)
    let shardlog_merge_regressed =
        shardlog_merge_nanos.1 as f64 > shardlog_merge_nanos.0 as f64 * 1.25 + 2e6;
    let shardlog_json = obj(vec![
        ("bench", s("perf_sim/shardlog")),
        ("threads", num(shardlog_threads as f64)),
        ("rows", arr(shardlog_rows)),
        ("sharded_sync_never_slower", Json::Bool(!shardlog_sync_slow)),
        ("sharded_regions_never_slower", Json::Bool(!shardlog_region_slow)),
        ("merge_reduced_at_thrash_rung", Json::Bool(!shardlog_merge_regressed)),
        ("hash_identical_across_shard_counts", Json::Bool(!shardlog_hash_diverged)),
    ]);
    std::fs::write("bench_out/BENCH_shardlog.json", shardlog_json.to_string_pretty())
        .expect("write bench_out/BENCH_shardlog.json");
    println!("\nBENCH {}", shardlog_json.to_string_pretty());
    println!("wrote bench_out/BENCH_shardlog.json");

    let informer_json = obj(vec![
        ("bench", s("perf_sim/informer")),
        ("rows", arr(informer_rows)),
        ("delta_never_slower", Json::Bool(!informer_slow)),
        ("scrape_rows", arr(scrape_rows)),
        ("subscription_never_slower", Json::Bool(!scrape_slow)),
        ("one_pct_below_half_of_full", Json::Bool(scrape_sparse_fast)),
    ]);
    std::fs::write("bench_out/BENCH_informer.json", informer_json.to_string_pretty())
        .expect("write bench_out/BENCH_informer.json");
    println!("\nBENCH {}", informer_json.to_string_pretty());
    println!("wrote bench_out/BENCH_informer.json");

    if mismatches > 0 {
        eprintln!("FAIL: {mismatches} sweep cases diverged between kernel modes");
        std::process::exit(1);
    }
    // CI gate: the event kernel must never be slower than the seed's
    // per-second loop (the paper-reproduction target is >= 5x; CI keeps a
    // conservative floor so shared-runner noise can't flake the build)
    if speedup < 1.0 {
        eprintln!("FAIL: event kernel slower than the per-second loop ({speedup:.2}x)");
        std::process::exit(1);
    }
    // CI gate: the delta informer must never be slower than the relist
    // informer it replaced (BENCH_informer.json carries the real ratios)
    if informer_slow {
        eprintln!("FAIL: delta informer sync slower than a full relist");
        std::process::exit(1);
    }
    // CI gates: subscription sampling must never cost more than the full
    // pass, and a 1 % interest set must scrape in well under half the
    // full-fleet cost (the point of per-pod subscriptions)
    if scrape_slow {
        eprintln!("FAIL: subscription scrape pass slower than the full-fleet pass");
        std::process::exit(1);
    }
    if !scrape_sparse_fast {
        eprintln!("FAIL: 1% subscription scrape not measurably below the full pass");
        std::process::exit(1);
    }
    // CI gates: the batched decision plane. Divergence means the SoA
    // batch is not the bit-identical drop-in it claims to be; the two
    // speed gates are the reason the plane batches (and parallelizes)
    // at all — BENCH_decide.json carries the real ratios.
    if decide_diverged {
        eprintln!("FAIL: decide planes diverged (scalar vs batched vs parallel batch)");
        std::process::exit(1);
    }
    if decide_batched_slow {
        eprintln!("FAIL: batched decide pass slower than the scalar loop");
        std::process::exit(1);
    }
    if decide_parallel_slow {
        eprintln!("FAIL: parallel batched decide slower than the serial batch");
        std::process::exit(1);
    }
    // CI gates: the sharded event-log control plane. Hash divergence means
    // sharding changed the event stream (it must be a pure layout change);
    // the speed gates are the reason the log shards at all —
    // BENCH_shardlog.json carries the real ratios.
    if shardlog_hash_diverged {
        eprintln!("FAIL: event-stream FNV hash diverged across shard counts");
        std::process::exit(1);
    }
    if shardlog_sync_slow {
        eprintln!("FAIL: sharded-log informer sync slower than the unified log");
        std::process::exit(1);
    }
    if shardlog_region_slow {
        eprintln!("FAIL: sharded-log stepping regions slower than the unified log");
        std::process::exit(1);
    }
    if shardlog_merge_regressed {
        eprintln!("FAIL: coordinator merge did not shrink under the sharded log at the thrash rung");
        std::process::exit(1);
    }
}
