//! The admission-surface contract: every coordinator mutation flows
//! through the typed `ApiClient` and surfaces as an API-layer event in
//! `watch()`; admission/patch edge cases behave like kube-apiserver.

use arcv::coordinator::controller::{run_to_completion, Controller};
use arcv::policy::arcv::{ArcvParams, ArcvPolicy};
use arcv::policy::vpa::VpaSimPolicy;
use arcv::simkube::{
    ApiClient, ApiError, Cluster, EventKind, Node, Outcome, PodPhase, ResourceSpec, SwapDevice,
    Verb,
};
use arcv::workloads::{build, AppId};

fn ramp_process(start: f64, end: f64, dur: f64) -> Box<dyn arcv::simkube::MemoryProcess> {
    struct Ramp(f64, f64, f64);
    impl arcv::simkube::MemoryProcess for Ramp {
        fn usage_gb(&self, t: f64) -> f64 {
            self.0 + (self.1 - self.0) * (t / self.2).clamp(0.0, 1.0)
        }
        fn duration_secs(&self) -> f64 {
            self.2
        }
        fn name(&self) -> &str {
            "ramp"
        }
    }
    Box::new(Ramp(start, end, dur))
}

/// Satellite regression: the api.rs module doc claims "never direct
/// mutation of kubelet state". Every applied coordinator action must be
/// visible in the API watch stream — patches as `ResizeIssued`, restarts
/// as `PodRestarted`.
#[test]
fn every_coordinator_action_surfaces_in_watch() {
    // a) the OOM/restart-heavy VPA baseline
    let mut c = Cluster::single_node(Node::new("w0", 64.0, SwapDevice::disabled()));
    let id = c.create_pod("app", ResourceSpec::memory_exact(0.6), ramp_process(1.0, 3.0, 300.0));
    let mut ctl = Controller::new();
    ctl.manage(id, Box::new(VpaSimPolicy::new(0.6)));
    run_to_completion(&mut c, &mut ctl, 100_000);
    assert!(c.pod(id).is_done());

    let applied = |verb: Verb, ctl: &Controller| {
        ctl.actions()
            .iter()
            .filter(|a| a.verb == verb && a.outcome == Outcome::Applied && !a.dry_run)
            .count()
    };
    let (events, _) = ApiClient::watch(&c, 0).unwrap();
    let resize_events = events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::ResizeIssued { .. }))
        .count();
    let restart_events = events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::PodRestarted { .. }))
        .count();
    assert!(applied(Verb::Restart, &ctl) > 0, "VPA run must restart");
    assert_eq!(applied(Verb::Patch, &ctl), resize_events);
    assert_eq!(applied(Verb::Restart, &ctl), restart_events);

    // b) the resize-heavy ARC-V path
    let mut c = Cluster::single_node(Node::new("w0", 64.0, SwapDevice::hdd(32.0)));
    let id = c.create_pod("app", ResourceSpec::memory_exact(12.0), ramp_process(4.0, 4.0, 900.0));
    let mut ctl = Controller::new();
    ctl.manage(id, Box::new(ArcvPolicy::new(12.0, ArcvParams::default())));
    run_to_completion(&mut c, &mut ctl, 100_000);
    let (events, _) = ApiClient::watch(&c, 0).unwrap();
    let resize_events = events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::ResizeIssued { .. }))
        .count();
    assert!(applied(Verb::Patch, &ctl) > 0, "ARC-V run must resize");
    assert_eq!(applied(Verb::Patch, &ctl), resize_events);
}

#[test]
fn nan_and_inf_memory_rejected_at_admission() {
    let mut c = Cluster::single_node(Node::new("w0", 64.0, SwapDevice::disabled()));
    let mut api = ApiClient::new();
    for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -1.0] {
        let err = api
            .create_pod(&mut c, "bad", ResourceSpec::memory_exact(bad), ramp_process(1.0, 1.0, 10.0))
            .unwrap_err();
        assert!(matches!(err, ApiError::Admission(_)), "{bad} admitted: {err}");
    }
    assert_eq!(c.pods.len(), 0, "nothing was created");

    // same rules on the patch path
    let id = api
        .create_pod(&mut c, "ok", ResourceSpec::memory_exact(2.0), ramp_process(1.0, 1.0, 100.0))
        .unwrap();
    for bad in [f64::NAN, f64::INFINITY, 0.0, -3.0] {
        assert!(matches!(
            api.patch_pod_memory(&mut c, id, bad, None),
            Err(ApiError::Patch(_))
        ));
    }
    // all rejections are in the audit log with reasons
    assert_eq!(
        api.actions().iter().filter(|a| a.outcome == Outcome::Rejected).count(),
        8
    );
}

#[test]
fn patch_on_pending_pod_is_effective_immediately() {
    // 8 GB node, 32 GB request → unschedulable, stays Pending
    let mut c = Cluster::single_node(Node::new("w0", 8.0, SwapDevice::disabled()));
    let mut api = ApiClient::new();
    let id = api
        .create_pod(&mut c, "big", ResourceSpec::memory_exact(32.0), ramp_process(1.0, 1.0, 10.0))
        .unwrap();
    assert_eq!(c.pod(id).phase, PodPhase::Pending);
    let rv = api.patch_pod_memory(&mut c, id, 4.0, Some(1)).unwrap();
    assert_eq!(rv, 2);
    // no running container → nothing for the kubelet to sync
    assert_eq!(c.pod(id).spec.memory_limit_gb(), Some(4.0));
    assert_eq!(c.pod(id).effective_limit_gb, 4.0);
    assert!(c.pod(id).pending_resize.is_none());
}

#[test]
fn dry_run_leaves_cluster_untouched() {
    let mut c = Cluster::single_node(Node::new("w0", 64.0, SwapDevice::hdd(16.0)));
    let mut api = ApiClient::new();
    let id = api
        .create_pod(&mut c, "a", ResourceSpec::memory_exact(2.0), ramp_process(1.0, 1.0, 100.0))
        .unwrap();
    c.run_until(10, |_| false);
    let events_before = c.events.retained_len();
    let rv_before = c.pod(id).resource_version;
    let spec_before = c.pod(id).spec;

    // valid dry-runs validate without mutating
    api.dry_run_create(&c, "another", &ResourceSpec::memory_exact(1.0)).unwrap();
    api.dry_run_patch(&c, id, 3.0, Some(rv_before)).unwrap();
    // invalid dry-runs report the same errors the real calls would
    assert!(matches!(
        api.dry_run_create(&c, "Bad_Name", &ResourceSpec::memory_exact(1.0)),
        Err(ApiError::Admission(_))
    ));
    assert!(matches!(
        api.dry_run_patch(&c, id, f64::NAN, None),
        Err(ApiError::Patch(_))
    ));
    assert_eq!(
        api.dry_run_patch(&c, id, 3.0, Some(999)),
        Err(ApiError::Conflict { pod: id, expected: 999, actual: rv_before })
    );

    assert_eq!(c.pods.len(), 1);
    assert_eq!(c.events.retained_len(), events_before);
    assert_eq!(c.pod(id).resource_version, rv_before);
    assert_eq!(c.pod(id).spec, spec_before);
    assert!(c.pod(id).pending_resize.is_none());
    // ... but the attempts are all audited as dry-run
    assert_eq!(api.actions().iter().filter(|a| a.dry_run).count(), 5);
}

#[test]
fn two_clients_conflict_on_stale_resource_version() {
    let mut c = Cluster::single_node(Node::new("w0", 64.0, SwapDevice::hdd(16.0)));
    let mut alice = ApiClient::new();
    let mut bob = ApiClient::new();
    let id = alice
        .create_pod(&mut c, "shared", ResourceSpec::memory_exact(4.0), ramp_process(1.0, 1.0, 500.0))
        .unwrap();
    c.run_until(5, |_| false);
    alice.sync(&mut c);
    bob.sync(&mut c);
    let rv_a = alice.cached(id).unwrap().resource_version;
    let rv_b = bob.cached(id).unwrap().resource_version;
    assert_eq!(rv_a, rv_b);

    // Alice lands first; Bob's decision was made against a stale view.
    alice.patch_pod_memory(&mut c, id, 5.0, Some(rv_a)).unwrap();
    let err = bob.patch_pod_memory(&mut c, id, 3.0, Some(rv_b)).unwrap_err();
    assert!(matches!(err, ApiError::Conflict { .. }), "{err}");
    // Bob re-syncs and retries cleanly.
    bob.sync(&mut c);
    let fresh = bob.cached(id).unwrap().resource_version;
    bob.patch_pod_memory(&mut c, id, 3.0, Some(fresh)).unwrap();
    assert_eq!(c.pod(id).spec.memory_limit_gb(), Some(3.0));
}

/// The admission chain is extensible: a quota plugin can cap creates.
#[test]
fn custom_admission_plugin_participates_in_chain() {
    struct MaxRequestQuota(f64);
    impl arcv::simkube::AdmissionPlugin for MaxRequestQuota {
        fn name(&self) -> &'static str {
            "MaxRequestQuota"
        }
        fn review(
            &self,
            _cluster: &Cluster,
            req: &arcv::simkube::AdmissionRequest,
        ) -> Result<(), String> {
            if let arcv::simkube::AdmissionRequest::Create { spec, .. } = req {
                if spec.memory_request_gb() > self.0 {
                    return Err(format!(
                        "request {} GB exceeds tenant quota {} GB",
                        spec.memory_request_gb(),
                        self.0
                    ));
                }
            }
            Ok(())
        }
    }

    let mut c = Cluster::single_node(Node::new("w0", 256.0, SwapDevice::disabled()));
    let mut api = ApiClient::new();
    api.push_plugin(Box::new(MaxRequestQuota(8.0)));
    let err = api
        .create_pod(&mut c, "hog", ResourceSpec::memory_exact(32.0), Box::new(build(AppId::Minife, 1)))
        .unwrap_err();
    assert!(matches!(err, ApiError::Admission(ref m) if m.contains("quota")), "{err}");
    assert!(api
        .create_pod(&mut c, "ok", ResourceSpec::memory_exact(4.0), Box::new(build(AppId::Kripke, 1)))
        .is_ok());
}
