//! The kernel equivalence suite: the event-driven clock — serial AND
//! sharded, at every tested worker count — must reproduce the 1 s-tick
//! reference **bit for bit**: same `RunResult` (counters AND float
//! integrals: coasts accumulate term-by-term with the same rounding),
//! same event-stream order — on every registered app × the four
//! single-pod policies, and through the scenario engine's churn paths
//! (arrivals, faults, drain, kill, leak, requeue) across several seeds.
//! The sharded event store adds a second axis: every event-shard layout
//! ({1, 2, pool-derived} node→shard maps) must reproduce the same merged
//! stream, hash, and informer caches at every thread count.
//!
//! This is the contract that lets `harness::run` and
//! `scenario::run_scenario` default to `KernelMode::EventDriven`, and
//! that makes `KernelMode::Sharded` safe to opt into at fleet scale.

use arcv::coordinator::DecidePlane;
use arcv::harness::{
    run_with_mode, run_with_mode_plane, ExperimentConfig, PolicyKind, RunOutput, SwapKind,
};
use arcv::policy::arcv::{ArcvParams, NativeFleet};
use arcv::scenario::{
    run_scenario_mode, Arrivals, Fault, ScenarioPolicy, ScenarioSpec, WorkloadMix,
};
use arcv::simkube::KernelMode;
use arcv::workloads::AppId;

/// The four registered policy environments of the suite. Rebuilt per call
/// because `PolicyKind` holds boxed backends (not `Clone`).
fn case(app: AppId, i: usize) -> (ExperimentConfig, PolicyKind) {
    match i {
        0 => (
            ExperimentConfig::arcv_env(app),
            PolicyKind::ArcvNative(ArcvParams::default()),
        ),
        1 => (ExperimentConfig::vpa_env(app), PolicyKind::VpaSim),
        2 => (ExperimentConfig::arcv_env(app), PolicyKind::Fixed),
        _ => (ExperimentConfig::arcv_env(app), PolicyKind::Oracle),
    }
}

const CASE_NAMES: [&str; 4] = ["arcv", "vpa-sim", "fixed", "oracle"];

/// The sharded worker counts under test: single worker, two workers, and
/// whatever the machine offers (`0`). Results must be identical at all
/// of them — thread count may only change wall-clock, never state.
const SHARD_COUNTS: [usize; 3] = [1, 2, 0];

fn run_case(app: AppId, i: usize, mode: KernelMode) -> RunOutput {
    let (cfg, kind) = case(app, i);
    run_with_mode(&cfg, kind, mode)
}

/// All three kernel modes run on the DELTA informer (PR 5): whatever the
/// wake cadence, the controller's informer must LIST once and replay
/// watch records ever after — a relist mid-run would mean the delta plane
/// broke down (and would silently reintroduce the O(pods) wake cost).
fn assert_delta_informer(label: &str, out: &RunOutput) {
    assert!(
        out.informer.syncs >= 1,
        "{label}: the controller never synced its informer"
    );
    assert!(
        out.informer.relists <= 1,
        "{label}: informer relisted {} times (only the initial LIST is allowed)",
        out.informer.relists
    );
}

#[test]
fn nine_apps_times_four_policies_match_bit_for_bit() {
    for app in AppId::all() {
        for i in 0..4 {
            let reference = run_case(app, i, KernelMode::Lockstep);
            let event = run_case(app, i, KernelMode::EventDriven);
            // the whole RunResult — integer counters, f64 integrals, and
            // the downsampled report series — must be identical
            assert_eq!(
                reference.result, event.result,
                "{app}/{} RunResult diverged",
                CASE_NAMES[i]
            );
            assert_eq!(
                reference.events, event.events,
                "{app}/{} EventLog diverged",
                CASE_NAMES[i]
            );
            assert!(
                event.stats.events <= reference.stats.events,
                "{app}/{}: event kernel visited more ticks ({}) than lockstep ({})",
                CASE_NAMES[i],
                event.stats.events,
                reference.stats.events
            );
            assert_delta_informer(&format!("{app}/{} lockstep", CASE_NAMES[i]), &reference);
            assert_delta_informer(&format!("{app}/{} event", CASE_NAMES[i]), &event);
            // the sharded path, at every tested worker count, against the
            // same lockstep reference
            for threads in SHARD_COUNTS {
                let sharded = run_case(app, i, KernelMode::Sharded { threads });
                assert_eq!(
                    reference.result, sharded.result,
                    "{app}/{} RunResult diverged (sharded, threads={threads})",
                    CASE_NAMES[i]
                );
                assert_eq!(
                    reference.events, sharded.events,
                    "{app}/{} EventLog diverged (sharded, threads={threads})",
                    CASE_NAMES[i]
                );
                assert_delta_informer(
                    &format!("{app}/{} sharded/{threads}", CASE_NAMES[i]),
                    &sharded,
                );
            }
        }
    }
}

/// The kernel modes the decide-plane cells run under (`Sharded {0}`
/// covers the parallel stepping regions at whatever the machine offers;
/// per-worker-count coverage is the sharded suite above).
const PLANE_MODES: [KernelMode; 3] = [
    KernelMode::Lockstep,
    KernelMode::EventDriven,
    KernelMode::Sharded { threads: 0 },
];

#[test]
fn decide_planes_match_bit_for_bit_in_every_cell() {
    // the batched-decision-plane contract: the SoA `decide_batch` route
    // is a perf refactor, not a behaviour change. Every policy ×
    // kernel-mode cell must produce the same RunResult (counters AND
    // float integrals) and the same EventLog with the batch plane forced
    // as with the scalar per-pod loop.
    for app in AppId::all() {
        for i in 0..4 {
            for mode in PLANE_MODES {
                let (cfg, kind) = case(app, i);
                let scalar = run_with_mode_plane(&cfg, kind, mode, DecidePlane::Scalar);
                let (cfg, kind) = case(app, i);
                let batched = run_with_mode_plane(&cfg, kind, mode, DecidePlane::Batched);
                assert_eq!(
                    scalar.result, batched.result,
                    "{app}/{} RunResult diverged between decide planes ({mode:?})",
                    CASE_NAMES[i]
                );
                assert_eq!(
                    scalar.events, batched.events,
                    "{app}/{} EventLog diverged between decide planes ({mode:?})",
                    CASE_NAMES[i]
                );
            }
        }
    }
}

#[test]
fn fleet_decide_planes_match_bit_for_bit() {
    // the fleet controller routes the same SoA batch through its
    // DecisionBackend on both planes (one batch ABI); the planes may
    // only differ in how the due-set reaches the policy, never in state
    let fleet = |app: AppId| {
        (
            ExperimentConfig::arcv_env(app),
            PolicyKind::ArcvFleet(
                ArcvParams::default(),
                Box::new(NativeFleet::new(64, ArcvParams::default().window)),
            ),
        )
    };
    for app in [AppId::Kripke, AppId::Lulesh, AppId::Bfs] {
        for mode in PLANE_MODES {
            let (cfg, kind) = fleet(app);
            let scalar = run_with_mode_plane(&cfg, kind, mode, DecidePlane::Scalar);
            let (cfg, kind) = fleet(app);
            let batched = run_with_mode_plane(&cfg, kind, mode, DecidePlane::Batched);
            assert_eq!(
                scalar.result, batched.result,
                "{app}/arcv-fleet RunResult diverged between decide planes ({mode:?})"
            );
            assert_eq!(
                scalar.events, batched.events,
                "{app}/arcv-fleet EventLog diverged between decide planes ({mode:?})"
            );
        }
    }
}

#[test]
fn event_kernel_skips_most_ticks_on_the_app_sweep() {
    // the point of the kernel: quiescent stretches are jumped, so the
    // event loop runs far fewer iterations than seconds simulated
    let out = run_case(AppId::Kripke, 2, KernelMode::EventDriven); // fixed policy
    assert!(out.result.completed);
    assert!(
        out.stats.events * 3 < out.stats.sim_ticks,
        "expected <1/3 of ticks visited, got {} events for {} ticks",
        out.stats.events,
        out.stats.sim_ticks
    );
}

fn churn_spec() -> ScenarioSpec {
    ScenarioSpec::new("equiv-churn")
        .pool("hi", 2, 64.0, SwapKind::Hdd(32.0))
        .pool("lo", 1, 32.0, SwapKind::Ssd(16.0))
        .arrivals(Arrivals::Bursty { period_secs: 60, burst: 3 })
        .jobs(9)
        .mix(WorkloadMix::uniform(&[
            AppId::Amr,
            AppId::Cm1,
            AppId::Kripke,
            AppId::Lulesh,
            AppId::Sputnipic,
        ]))
        .fault(Fault::KillRandomPod { at: 120 })
        .fault(Fault::LeakyPod {
            at: 200,
            base_gb: 2.0,
            leak_gb_per_sec: 0.01,
            lifetime_secs: 400.0,
        })
        .fault(Fault::DrainNode { at: 300, node: 2 })
        .max_ticks(60_000)
}

#[test]
fn scenario_engine_matches_reference_through_churn() {
    // ≥ 3 seeds × every kernel flavor: the churn paths (arrivals, faults,
    // drain, kill, leak, requeue) must agree bit-for-bit at every tested
    // thread count
    let spec = churn_spec();
    for seed in [7u64, 11, 23] {
        for policy in [
            ScenarioPolicy::Arcv(ArcvParams::default()),
            ScenarioPolicy::VpaSim,
            ScenarioPolicy::Fixed,
        ] {
            let reference = run_scenario_mode(&spec, policy, seed, KernelMode::Lockstep);
            let mut contenders = vec![(
                "event".to_string(),
                run_scenario_mode(&spec, policy, seed, KernelMode::EventDriven),
            )];
            for threads in SHARD_COUNTS {
                contenders.push((
                    format!("sharded/{threads}"),
                    run_scenario_mode(&spec, policy, seed, KernelMode::Sharded { threads }),
                ));
            }
            for (label, run) in &contenders {
                assert_eq!(
                    reference.outcome,
                    run.outcome,
                    "{} seed {seed} outcome diverged ({label})",
                    policy.label()
                );
                assert_eq!(
                    reference.cluster.events.snapshot(),
                    run.cluster.events.snapshot(),
                    "{} seed {seed} EventLog diverged ({label})",
                    policy.label()
                );
                // churn or not, every mode rides the delta informer
                assert!(
                    run.informer.relists <= 1,
                    "{} seed {seed} ({label}): informer relisted {} times",
                    policy.label(),
                    run.informer.relists
                );
            }
        }
    }
}

/// The region-adversarial storm: 8 equal nodes, a bursty backlog that
/// populates all of them, then ten staggered mid-life leakers whose
/// footprints blow through their limits (swap thrash where the pool has
/// swap, OOM churn where it doesn't) while the policy's resize storms
/// keep `pending_resize` set fleet-wide — so stepping regions run with
/// many simultaneously hot nodes, exercising the shard partition and the
/// deterministic buffer merge rather than a single-hot-node fast path.
fn region_storm_spec() -> ScenarioSpec {
    let mut spec = ScenarioSpec::new("equiv-region-storm")
        .pool("hot", 8, 10.0, SwapKind::Hdd(8.0))
        .arrivals(Arrivals::Bursty { period_secs: 20, burst: 6 })
        .jobs(24)
        .mix(WorkloadMix::uniform(&[
            AppId::Amr,
            AppId::Cm1,
            AppId::Kripke,
            AppId::Lulesh,
        ]))
        .fault(Fault::KillRandomPod { at: 260 })
        .fault(Fault::KillRandomPod { at: 410 })
        .max_ticks(4_000);
    for i in 0..10u64 {
        spec = spec.fault(Fault::LeakyPod {
            at: 60 + i * 20,
            base_gb: 1.5,
            leak_gb_per_sec: 0.02 + i as f64 * 0.002,
            lifetime_secs: 500.0,
        });
    }
    spec
}

/// Distinct nodes that went hot during a run, read off the event stream:
/// swap spills, OOM kills, and applied resizes attribute to the pod's
/// current placement (tracked through `PodScheduled`), pressure evictions
/// carry their node directly.
fn hot_nodes_touched(events: &[arcv::simkube::Event]) -> std::collections::BTreeSet<usize> {
    use arcv::simkube::EventKind;
    let mut placed: std::collections::HashMap<usize, usize> = std::collections::HashMap::new();
    let mut hot = std::collections::BTreeSet::new();
    for e in events {
        match e.kind {
            EventKind::PodScheduled { node } => {
                placed.insert(e.pod, node);
            }
            EventKind::Evicted { node, .. } => {
                hot.insert(node);
            }
            EventKind::SwappedOut { .. }
            | EventKind::OomKilled { .. }
            | EventKind::ResizeApplied { .. } => {
                if let Some(&n) = placed.get(&e.pod) {
                    hot.insert(n);
                }
            }
            _ => {}
        }
    }
    hot
}

#[test]
fn region_storm_matches_reference_at_every_thread_count() {
    let spec = region_storm_spec();
    for policy in [ScenarioPolicy::Arcv(ArcvParams::default()), ScenarioPolicy::VpaSim] {
        let reference = run_scenario_mode(&spec, policy, 17, KernelMode::Lockstep);
        // the storm must be what it claims: proof-defeating activity
        // spread across every node of the pool, not one hot corner.
        // (Arcv's 1.2× initial sizing spreads the backlog over all 8
        // nodes; VPA-sim's 0.2× requests may pack tighter, so the spread
        // guarantee is asserted on the Arcv run.)
        if matches!(policy, ScenarioPolicy::Arcv(_)) {
            let hot = hot_nodes_touched(&reference.cluster.events.snapshot());
            assert!(hot.len() >= 8, "storm only heated nodes {hot:?}");
        }
        let event = run_scenario_mode(&spec, policy, 17, KernelMode::EventDriven);
        assert_eq!(reference.outcome, event.outcome, "{}", policy.label());
        assert_eq!(
            reference.cluster.events.snapshot(),
            event.cluster.events.snapshot(),
            "{} EventLog diverged (event)",
            policy.label()
        );
        for threads in SHARD_COUNTS {
            let sharded = run_scenario_mode(&spec, policy, 17, KernelMode::Sharded { threads });
            assert_eq!(
                reference.outcome,
                sharded.outcome,
                "{} outcome diverged (threads={threads})",
                policy.label()
            );
            assert_eq!(
                reference.cluster.events.snapshot(),
                sharded.cluster.events.snapshot(),
                "{} EventLog diverged (threads={threads})",
                policy.label()
            );
            assert_eq!(
                reference.cluster.events.revision(),
                sharded.cluster.events.revision(),
                "{} log revision diverged (threads={threads})",
                policy.label()
            );
            assert!(
                sharded.cluster.coast_stats.regions_entered > 0,
                "{} (threads={threads}): the storm never entered a stepping region: {:?}",
                policy.label(),
                sharded.cluster.coast_stats
            );
        }
    }
}

/// FNV-1a over the debug rendering of every event, in merged stream
/// order — the same event-stream fingerprint the bench gates use.
fn event_stream_hash(events: &[arcv::simkube::Event]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for e in events {
        for b in format!("{e:?}").bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

#[test]
fn sharded_log_matches_unified_at_every_shard_and_thread_count() {
    // the tentpole acceptance pin: the sharded event store is a pure
    // re-layout. Shard counts {1, 2, pool-derived} × kernel modes
    // {event, sharded × threads {1, 2, N}} must reproduce the
    // single-shard lockstep reference bit for bit — stream, hash,
    // revision, outcome, and the informer caches a fresh full sync
    // builds from the end state.
    let policy = ScenarioPolicy::Arcv(ArcvParams::default());
    let reference =
        run_scenario_mode(&churn_spec().event_shards(1), policy, 7, KernelMode::Lockstep);
    let ref_events = reference.cluster.events.snapshot();
    let ref_hash = event_stream_hash(&ref_events);
    let mut ref_api = arcv::simkube::ApiClient::new();
    let mut ref_cluster = reference.cluster;
    ref_api.sync(&mut ref_cluster);
    // shard layouts: unified, forced two-chunk, and pool-derived (the
    // churn spec declares two pools, so the default map is [0, 0, 1])
    let layouts: [(&str, ScenarioSpec); 3] = [
        ("1-shard", churn_spec().event_shards(1)),
        ("2-shard", churn_spec().event_shards(2)),
        ("pool-shard", churn_spec()),
    ];
    for (layout, spec) in layouts {
        let mut runs = vec![(
            format!("{layout}/event"),
            run_scenario_mode(&spec, policy, 7, KernelMode::EventDriven),
        )];
        for threads in SHARD_COUNTS {
            runs.push((
                format!("{layout}/sharded-{threads}"),
                run_scenario_mode(&spec, policy, 7, KernelMode::Sharded { threads }),
            ));
        }
        for (label, run) in runs {
            assert_eq!(reference.outcome, run.outcome, "{label}: outcome diverged");
            let events = run.cluster.events.snapshot();
            assert_eq!(ref_events, events, "{label}: event stream diverged");
            assert_eq!(ref_hash, event_stream_hash(&events), "{label}: stream hash diverged");
            assert_eq!(
                ref_cluster.events.revision(),
                run.cluster.events.revision(),
                "{label}: revision diverged"
            );
            // a fresh informer LISTing the end state sees identical
            // views and phase indexes
            let mut api = arcv::simkube::ApiClient::new();
            let mut cluster = run.cluster;
            api.sync(&mut cluster);
            assert!(
                ref_api.cached_views().eq(api.cached_views()),
                "{label}: cached views diverged"
            );
            assert_eq!(ref_api.running(), api.running(), "{label}: Running index diverged");
            assert_eq!(
                ref_api.oom_killed(),
                api.oom_killed(),
                "{label}: OomKilled index diverged"
            );
        }
    }
}

#[test]
fn starved_queue_idles_to_the_budget_identically() {
    // drain the only node: everything re-enters the queue with no
    // capacity anywhere; every kernel must report the same stuck state at
    // exactly max_ticks (the event kernels jump there, the reference
    // idles tick by tick)
    let spec = ScenarioSpec::new("equiv-starved")
        .pool("n", 1, 64.0, SwapKind::Disabled)
        .mix(WorkloadMix::uniform(&[AppId::Kripke]))
        .arrivals(Arrivals::Backlog)
        .jobs(2)
        .fault(Fault::DrainNode { at: 100, node: 0 })
        .max_ticks(400);
    let reference = run_scenario_mode(&spec, ScenarioPolicy::Fixed, 9, KernelMode::Lockstep);
    let event = run_scenario_mode(&spec, ScenarioPolicy::Fixed, 9, KernelMode::EventDriven);
    assert_eq!(reference.outcome, event.outcome);
    assert_eq!(reference.cluster.events.snapshot(), event.cluster.events.snapshot());
    assert_eq!(event.outcome.wall_ticks, 400);
    assert_eq!(event.outcome.stuck_pending, 2);
    for threads in SHARD_COUNTS {
        let sharded =
            run_scenario_mode(&spec, ScenarioPolicy::Fixed, 9, KernelMode::Sharded { threads });
        assert_eq!(reference.outcome, sharded.outcome, "threads={threads}");
        assert_eq!(
            reference.cluster.events.snapshot(), sharded.cluster.events.snapshot(),
            "threads={threads}"
        );
    }
}
