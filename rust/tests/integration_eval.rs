//! Integration tests across the whole stack: workloads → cluster →
//! policies → harness, including the XLA-backed fleet path when artifacts
//! are present.

use arcv::coordinator::controller::{run_to_completion, Tick};
use arcv::coordinator::fleet::FleetController;
use arcv::harness::{ratio_row, run, ExperimentConfig, PolicyKind};
use arcv::policy::arcv::{ArcvParams, NativeFleet};
use arcv::runtime::{Engine, Manifest, XlaFleet};
use arcv::simkube::cluster::Cluster;
use arcv::simkube::node::Node;
use arcv::simkube::resources::ResourceSpec;
use arcv::simkube::swap::SwapDevice;
use arcv::workloads::{build, AppId};

/// Growth apps from a 20% initial allocation under the VPA simulator must
/// show the paper's pathology: repeated OOM restarts, large exec blowup —
/// while ARC-V avoids OOM entirely (Fig 4's headline).
#[test]
fn vpa_vs_arcv_shape_on_growth_app() {
    let app = AppId::Sputnipic; // fastest growth app (210s)
    let vpa = run(&ExperimentConfig::vpa_env(app), PolicyKind::VpaSim);
    let arcv = run(
        &ExperimentConfig::arcv_env(app),
        PolicyKind::ArcvNative(ArcvParams::default()),
    );
    assert!(vpa.completed && arcv.completed);
    assert!(vpa.restarts >= 5, "staircase restarts: {}", vpa.restarts);
    assert_eq!(arcv.oom_count, 0, "ARC-V eliminates OOMs");
    let row = ratio_row(&vpa, &arcv, 210.0);
    assert!(row.exectime_ratio > 1.5, "VPA pays restarts: {}", row.exectime_ratio);
    assert!(row.footprint_ratio > 0.5, "sane footprint ratio");
    assert!(
        row.arcv_overhead_pct < 3.0,
        "ARC-V overhead below 3% (paper §5): {}",
        row.arcv_overhead_pct
    );
}

/// The stable showcase (LAMMPS, Fig 5): ARC-V shrinks a grossly
/// over-provisioned tiny app by a large factor.
#[test]
fn arcv_shrinks_stable_lammps_hard() {
    let mut cfg = ExperimentConfig::arcv_env(AppId::Lammps);
    cfg.initial_frac = 10.0; // paper: VPA grossly over-allocates tiny apps
    let r = run(&cfg, PolicyKind::ArcvNative(ArcvParams::default()));
    assert!(r.completed);
    let over = cfg.initial_frac * 0.0237 * r.wall_secs as f64;
    assert!(
        r.provisioned_gbs < over / 2.0,
        "footprint {} must beat static {}",
        r.provisioned_gbs,
        over
    );
}

/// MiniFE's end-of-run spike (Fig 4/§5): when the provisioned limit sits
/// below the final spike (here: initial 90 % of max, as in the paper where
/// the limit had converged near live usage), swap absorbs the spike — no
/// OOM — at a visible execution-time cost, exactly what the paper reports.
#[test]
fn minife_uses_swap_and_survives() {
    let mut cfg = ExperimentConfig::arcv_env(AppId::Minife);
    cfg.initial_frac = 0.9; // 57.3 GB < the 63.7 GB end spike
    cfg.budget_mult = 20.0;
    let r = run(&cfg, PolicyKind::ArcvNative(ArcvParams::default()));
    assert!(r.completed);
    assert_eq!(r.oom_count, 0, "swap must absorb the spike, not the OOM killer");
    let max_swap = r
        .swap_series
        .iter()
        .map(|&(_, s)| s)
        .fold(0.0_f64, f64::max);
    assert!(max_swap > 0.0, "the final spike must touch swap");
    // the paper reports MiniFE as the one app with visible overhead
    assert!(r.wall_secs > 352, "swap thrash costs wall time: {}", r.wall_secs);
}

/// Fleet controller with the native backend equals the per-pod native
/// policy on the same workload (same decisions, same footprint).
#[test]
fn fleet_native_matches_per_pod_policy() {
    let params = ArcvParams::default();
    let per_pod = run(
        &ExperimentConfig::arcv_env(AppId::Kripke),
        PolicyKind::ArcvNative(params),
    );
    let fleet = run(
        &ExperimentConfig::arcv_env(AppId::Kripke),
        PolicyKind::ArcvFleet(params, Box::new(NativeFleet::new(64, params.window))),
    );
    assert_eq!(per_pod.wall_secs, fleet.wall_secs);
    let rel = (per_pod.provisioned_gbs - fleet.provisioned_gbs).abs() / per_pod.provisioned_gbs;
    assert!(rel < 0.02, "footprints agree: {rel}");
}

/// End-to-end with the AOT artifact on the decision path (the deployed
/// configuration). Requires `make artifacts`.
#[test]
fn xla_fleet_end_to_end_run() {
    let Ok(manifest) = Manifest::discover() else {
        eprintln!("SKIP xla_fleet_end_to_end_run: run `make artifacts` first");
        return;
    };
    let engine = Engine::cpu().unwrap();
    let params = ArcvParams::default();
    let fleet = XlaFleet::from_manifest(&engine, &manifest, 64).unwrap();
    let xla = run(
        &ExperimentConfig::arcv_env(AppId::Sputnipic),
        PolicyKind::ArcvFleet(params, Box::new(fleet)),
    );
    let native = run(
        &ExperimentConfig::arcv_env(AppId::Sputnipic),
        PolicyKind::ArcvNative(params),
    );
    assert!(xla.completed);
    assert_eq!(xla.oom_count, 0);
    assert_eq!(xla.wall_secs, native.wall_secs);
    let rel = (xla.provisioned_gbs - native.provisioned_gbs).abs() / native.provisioned_gbs;
    assert!(rel < 0.02, "xla within 2% of native footprint: {rel}");
}

/// Multi-tenancy (§5 Use cases): four right-sized apps co-locate on one
/// 256 GB node, all complete, no OOM, reservations never exceed capacity.
#[test]
fn multi_tenant_colocation_on_one_node() {
    let mut c = Cluster::single_node(Node::cloudlab("w0"));
    let params = ArcvParams::default();
    let apps = [AppId::Kripke, AppId::Cm1, AppId::Lulesh, AppId::Lammps];
    let mut ctl = FleetController::from_backend(Box::new(NativeFleet::new(64, params.window)), params);
    let mut ids = Vec::new();
    for (i, app) in apps.iter().enumerate() {
        let m = build(*app, 42 + i as u64);
        let init = m.max_gb * 1.2;
        let id = c.create_pod(app.name(), ResourceSpec::memory_exact(init), Box::new(m));
        ctl.manage(id, init);
        ids.push(id);
    }
    let mut max_reserved: f64 = 0.0;
    let start = c.now;
    while c.now - start < 60_000 && !c.all_done() {
        c.step();
        ctl.tick(&mut c);
        max_reserved = max_reserved.max(c.nodes[0].reserved_gb);
        assert!(c.nodes[0].reserved_gb <= c.nodes[0].capacity_gb + 1e-9);
    }
    for &id in &ids {
        assert!(c.pod(id).is_done(), "pod {id} finished");
        assert_eq!(c.events.count_ooms(id), 0);
    }
}

/// Prometheus exposition is served with all three container series for a
/// live pod (the metrics-pipeline contract third parties scrape). The pod
/// is managed by an ARC-V kernel, so it is subscribed on the scrape grid;
/// the cluster endpoint also serves the scrape-plane counters.
#[test]
fn prometheus_endpoint_contract() {
    use arcv::policy::arcv::ArcvPolicy;
    let mut c = Cluster::single_node(Node::new("w0", 64.0, SwapDevice::hdd(16.0)));
    let id = c.create_pod(
        "kripke-0",
        ResourceSpec::memory_exact(8.0),
        Box::new(build(AppId::Kripke, 1)),
    );
    let mut ctl = arcv::coordinator::Controller::new();
    ctl.manage(id, Box::new(ArcvPolicy::new(8.0, ArcvParams::default())));
    run_to_completion(&mut c, &mut ctl, 100);
    let mut names = std::collections::BTreeMap::new();
    names.insert(id, "kripke-0".to_string());
    let text = c.metrics.prometheus_text(&names);
    for metric in [
        "container_memory_usage_bytes",
        "container_memory_rss",
        "container_memory_swap",
    ] {
        assert!(text.contains(&format!("{metric}{{pod=\"kripke-0\"}}")), "{metric}");
        assert!(text.contains(&format!("# TYPE {metric} gauge")), "{metric} TYPE");
    }
    // the cluster-level endpoint stacks the scrape-plane self-exposition
    // on top of the per-pod series
    let full = c.prometheus_text();
    assert!(full.contains("container_memory_usage_bytes{pod=\"kripke-0\"}"));
    assert!(full.contains("arcv_scrape_passes_total"));
    assert!(full.contains("arcv_scrape_subscribed_pods 1"));
}
