//! Integration tests for the scenario subsystem: churn (arrival → Pending
//! → requeue → placement after a departure frees capacity), fault
//! injectors firing exactly once at their scheduled tick, and the
//! serial/parallel determinism contract of the grid runner.

use arcv::harness::SwapKind;
use arcv::policy::arcv::ArcvParams;
use arcv::scenario::{
    build_schedule, run_grid, run_scenario, Arrivals, Fault, ScenarioPolicy, ScenarioSpec,
    WorkloadMix,
};
use arcv::simkube::EventKind;
use arcv::workloads::AppId;

/// Fixed policy + one 16 GB node + four kripke jobs (6.6 GB initial each):
/// exactly two fit; the other two must wait Pending until the first pair
/// completes and departs, then the requeue loop places them.
#[test]
fn queued_jobs_place_only_after_departures_free_capacity() {
    let spec = ScenarioSpec::new("queue")
        .pool("n", 1, 16.0, SwapKind::Disabled)
        .mix(WorkloadMix::uniform(&[AppId::Kripke]))
        .arrivals(Arrivals::Backlog)
        .jobs(4)
        .max_ticks(10_000);
    let run = run_scenario(&spec, ScenarioPolicy::Fixed, 1);

    assert_eq!(run.outcome.jobs_submitted, 4);
    assert_eq!(run.outcome.jobs_completed, 4);
    assert_eq!(run.outcome.stuck_pending, 0);

    // kripke runs 650 s; under Fixed nothing resizes, so the second pair
    // can only start once the first pair departs
    let starts: Vec<u64> = run
        .jobs
        .iter()
        .map(|j| run.cluster.pod(j.pod).started_at.expect("all started"))
        .collect();
    assert_eq!(starts.iter().filter(|&&t| t == 0).count(), 2);
    assert_eq!(starts.iter().filter(|&&t| t >= 650).count(), 2);
    // the initial no-fit surfaced as a scheduling failure, then requeued
    assert!(run
        .cluster
        .events
        .iter()
        .any(|e| matches!(e.kind, EventKind::SchedulingFailed { .. })));
    assert_eq!(run.outcome.pending_wait_secs, 2 * 650);
    // slowdowns: two at 1.0, two at 2.0 → p50 interpolates to 1.5
    assert!((run.outcome.slowdown_p50 - 1.5).abs() < 0.02, "{}", run.outcome.slowdown_p50);
    assert!(run.outcome.slowdown_p99 > 1.9);
}

/// Every fault injector fires exactly once, at exactly its scheduled tick.
#[test]
fn fault_injectors_fire_exactly_once_at_their_tick() {
    let spec = ScenarioSpec::new("faults")
        .pool("n", 1, 64.0, SwapKind::Disabled)
        .mix(WorkloadMix::uniform(&[AppId::Kripke]))
        .arrivals(Arrivals::Backlog)
        .jobs(2)
        .fault(Fault::LeakyPod {
            at: 30,
            base_gb: 1.0,
            leak_gb_per_sec: 0.005,
            lifetime_secs: 200.0,
        })
        .fault(Fault::KillRandomPod { at: 50 })
        .fault(Fault::DrainNode { at: 100, node: 0 })
        // the only node stays cordoned after the drain, so everything is
        // stuck Pending by design; stop soon after and check accounting
        .max_ticks(300);
    let run = run_scenario(&spec, ScenarioPolicy::Fixed, 9);

    let kills: Vec<u64> = run
        .cluster
        .events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::PodKilled { .. }))
        .map(|e| e.time)
        .collect();
    assert_eq!(kills, vec![50], "kill fires once, at t=50");

    let drains: Vec<(u64, usize)> = run
        .cluster
        .events
        .iter()
        .filter_map(|e| match e.kind {
            EventKind::NodeDrained { displaced, .. } => Some((e.time, displaced)),
            _ => None,
        })
        .collect();
    // at t=100 the node hosts both kripke pods and the leak pod
    assert_eq!(drains, vec![(100, 3)], "drain fires once, at t=100");

    // the leak pod was submitted at its scheduled tick and counted
    assert_eq!(run.outcome.jobs_submitted, 3);
    let leak = run.jobs.iter().find(|j| j.injected).expect("leak pod recorded");
    assert_eq!(leak.submit_at, 30);
    assert_eq!(leak.name, "leak-30");

    // post-drain: one cordoned node, no capacity anywhere → everything
    // re-enters the queue and is reported stuck at the hard stop
    assert_eq!(run.outcome.node_drains, 1);
    assert_eq!(run.outcome.fault_kills, 1);
    assert_eq!(run.outcome.stuck_pending, 3);
    assert_eq!(run.outcome.jobs_completed, 0);
}

/// The determinism contract: a parallel grid is bit-identical to the
/// serial reference, because every random stream derives from
/// `(run seed, job index)` — never from thread interleaving.
#[test]
fn parallel_grid_is_bit_identical_to_serial() {
    let specs = [ScenarioSpec::new("det")
        .pool("a", 2, 32.0, SwapKind::Hdd(16.0))
        .pool("b", 1, 16.0, SwapKind::Ssd(8.0))
        .mix(WorkloadMix::uniform(&[AppId::Sputnipic, AppId::Cm1, AppId::Amr]))
        .arrivals(Arrivals::Poisson { rate_per_min: 10.0 })
        .jobs(6)
        .fault(Fault::KillRandomPod { at: 150 })
        .max_ticks(30_000)];
    let policies = [
        ScenarioPolicy::Arcv(ArcvParams::default()),
        ScenarioPolicy::VpaSim,
    ];
    let seeds = [1, 2, 3, 4];

    let serial = run_grid(&specs, &policies, &seeds, 1);
    let parallel = run_grid(&specs, &policies, &seeds, 4);
    assert_eq!(serial.len(), 8);
    assert_eq!(serial, parallel, "parallel execution must not change results");

    // distinct seeds genuinely produce distinct runs (the streams are
    // seed-sensitive, not just reproducible)
    assert!(
        serial[0] != serial[1] || serial[1] != serial[2],
        "different seeds should differ somewhere"
    );
}

/// Per-job model seeds are a pure function of (run seed, job index), so
/// the schedule — and through it every workload trace — replays exactly.
#[test]
fn schedules_replay_exactly_per_seed() {
    let spec = ScenarioSpec::new("sched")
        .pool("n", 1, 64.0, SwapKind::Disabled)
        .mix(WorkloadMix::uniform(&[AppId::Kripke, AppId::Lulesh]))
        .arrivals(Arrivals::Poisson { rate_per_min: 3.0 })
        .jobs(25);
    assert_eq!(build_schedule(&spec, 123), build_schedule(&spec, 123));
    let a = build_schedule(&spec, 123);
    let b = build_schedule(&spec, 124);
    assert_ne!(a, b);
    // arrival times must be monotone (a queue, not a shuffle)
    assert!(a.windows(2).all(|w| w[0].submit_at <= w[1].submit_at));
}
