//! Property suite for the indexed scheduling queue: the incremental
//! waiting queue + free-capacity index behind `Cluster::schedule_pending`
//! must be indistinguishable from the linear-scan reference
//! (`Cluster::schedule_pending_scan`, which classifies waiting pods by a
//! full sweep over every pod ever created and places through the linear
//! scheduler) on randomized arrival / departure / eviction / drain /
//! kill / patch / restart sequences — same placements, same events, same
//! final cluster state, pass by pass.

use arcv::scenario::LeakProcess;
use arcv::simkube::{
    Cluster, ClusterConfig, MemoryProcess, Node, ResourceSpec, Strategy, SwapDevice,
};
use arcv::util::prop::{self, require};

/// A flat memory process (LeakProcess with zero leak): usage is constant
/// at `usage_gb` for `secs` application-seconds.
fn flat(usage_gb: f64, secs: f64) -> Box<dyn MemoryProcess> {
    Box::new(LeakProcess {
        base_gb: usage_gb,
        leak_gb_per_sec: 0.0,
        lifetime_secs: secs,
    })
}

fn build_cluster(caps: &[f64], strategy: Strategy) -> Cluster {
    let nodes: Vec<Node> = caps
        .iter()
        .enumerate()
        .map(|(i, &c)| Node::new(&format!("w{i}"), c, SwapDevice::disabled()))
        .collect();
    Cluster::new(
        nodes,
        ClusterConfig {
            scheduler: strategy,
            ..ClusterConfig::default()
        },
    )
}

#[test]
fn indexed_queue_is_equivalent_to_linear_scan_under_random_churn() {
    prop::check("sched-queue-vs-scan", 80, |g| {
        let n_nodes = g.usize(1, 4);
        let caps: Vec<f64> = (0..n_nodes).map(|_| g.f64(8.0, 48.0)).collect();
        let strategy = if g.bool(0.5) { Strategy::BestFit } else { Strategy::WorstFit };
        // cluster A places through the indexed queue, cluster B through
        // the full-scan + linear-scheduler reference; every other call is
        // identical
        let mut a = build_cluster(&caps, strategy);
        let mut b = build_cluster(&caps, strategy);
        let mut created = 0usize;
        for round in 0..40 {
            match g.usize(0, 7) {
                0 | 1 => {
                    // arrival: mixed sizes, sometimes unplaceable, with
                    // the occasional best-effort balloon to force
                    // pressure evictions (the requeue-conversion path)
                    let name = format!("p{created}");
                    let (spec, usage) = if g.bool(0.15) {
                        let u = g.f64(16.0, 96.0); // balloon: evicted soon
                        (ResourceSpec::best_effort(), u)
                    } else {
                        let req = g.f64(1.0, 24.0);
                        (ResourceSpec::memory_exact(req), req * g.f64(0.3, 0.9))
                    };
                    let secs = g.f64(10.0, 80.0);
                    a.create_pod(&name, spec, flat(usage, secs));
                    b.create_pod(&name, spec, flat(usage, secs));
                    created += 1;
                }
                2 => {
                    let ticks = g.u64(1, 15);
                    a.run_until(ticks, |_| false);
                    b.run_until(ticks, |_| false);
                }
                3 if created > 0 => {
                    let id = g.usize(0, created - 1);
                    a.kill_pod(id);
                    b.kill_pod(id);
                }
                4 if created > 0 => {
                    let id = g.usize(0, created - 1);
                    let gb = g.f64(1.0, 24.0);
                    a.patch_pod_memory(id, gb);
                    b.patch_pod_memory(id, gb);
                }
                5 if created > 0 => {
                    let id = g.usize(0, created - 1);
                    let gb = g.f64(1.0, 24.0);
                    a.restart_pod(id, gb);
                    b.restart_pod(id, gb);
                }
                6 => {
                    let node = g.usize(0, n_nodes - 1);
                    if g.bool(0.6) {
                        a.drain_node(node);
                        b.drain_node(node);
                    } else {
                        a.uncordon_node(node);
                        b.uncordon_node(node);
                    }
                }
                _ => {}
            }
            if g.bool(0.7) {
                let pa = a.schedule_pending();
                let pb = b.schedule_pending_scan();
                if pa != pb {
                    return Err(format!("round {round}: placed {pa} (indexed) vs {pb} (scan)"));
                }
            }
        }
        // settle: a couple of final passes + ticks, then compare state
        for _ in 0..3 {
            let pa = a.schedule_pending();
            let pb = b.schedule_pending_scan();
            require(pa == pb, "final passes place identically")?;
            a.run_until(3, |_| false);
            b.run_until(3, |_| false);
        }
        require(a.now == b.now, "clocks agree")?;
        require(
            a.events.snapshot() == b.events.snapshot(),
            "event logs must be identical",
        )?;
        for id in 0..a.pods.len() {
            if a.pod(id).phase != b.pod(id).phase || a.pod(id).node != b.pod(id).node {
                return Err(format!(
                    "pod {id}: {:?}@{:?} (indexed) vs {:?}@{:?} (scan)",
                    a.pod(id).phase,
                    a.pod(id).node,
                    b.pod(id).phase,
                    b.pod(id).node
                ));
            }
        }
        for n in 0..a.nodes.len() {
            if a.nodes[n].reserved_gb != b.nodes[n].reserved_gb {
                return Err(format!(
                    "node {n} reservation: {} vs {}",
                    a.nodes[n].reserved_gb, b.nodes[n].reserved_gb
                ));
            }
        }
        Ok(())
    });
}
