//! Runtime equivalence: the XLA fleet backend (AOT artifact via PJRT) must
//! match the native fleet backend on random batches — the test that proves
//! the deployed hot path computes the paper's policy.
//!
//! Requires `make artifacts`; skips (with a loud note) when absent so plain
//! `cargo test` still passes in a fresh checkout.

use arcv::policy::arcv::{ArcvParams, DecisionBackend, NativeFleet, PodState, State, STATE_LEN};
use arcv::runtime::{Engine, Manifest, XlaFleet};
use arcv::util::rng::Xoshiro256;

fn make_batch(
    rng: &mut Xoshiro256,
    n: usize,
    w: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut windows = vec![0f32; n * w];
    let mut swap = vec![0f32; n];
    let mut states = vec![0f32; n * STATE_LEN];
    for i in 0..n {
        let base = rng.uniform(0.05, 50.0);
        let kind = rng.below(4);
        for j in 0..w {
            let v = match kind {
                0 => base * (1.0 + 0.1 * j as f64), // growth
                1 => base * (1.0 + rng.uniform(-0.005, 0.005)), // flat
                2 => {
                    // drop in the middle
                    if j == w / 2 {
                        base * 0.5
                    } else {
                        base
                    }
                }
                _ => base * (1.0 + rng.uniform(-0.3, 0.3)), // noisy
            };
            windows[i * w + j] = v.max(1e-3) as f32;
        }
        swap[i] = if rng.next_f64() < 0.3 {
            rng.uniform(0.0, 1.0) as f32
        } else {
            0.0
        };
        let mut st = PodState::initial(base * rng.uniform(1.0, 2.0));
        st.state = match rng.below(3) {
            0 => State::Growing,
            1 => State::Dynamic,
            _ => State::Stable,
        };
        st.nosig = rng.below(4) as f64;
        st.persist = rng.below(4) as f64;
        st.gmax = base * rng.uniform(0.8, 1.5);
        st.pack(&mut states[i * STATE_LEN..(i + 1) * STATE_LEN]);
    }
    (windows, swap, states)
}

#[test]
fn xla_fleet_matches_native_fleet() {
    let Ok(manifest) = Manifest::discover() else {
        eprintln!("SKIP xla_fleet_matches_native_fleet: run `make artifacts` first");
        return;
    };
    let engine = Engine::cpu().expect("PJRT CPU client");
    let mut xla = XlaFleet::from_manifest(&engine, &manifest, 64).expect("load artifact");
    let w = xla.window();
    let mut native = NativeFleet::new(xla.batch(), w);
    let params = ArcvParams::default();

    let mut rng = Xoshiro256::new(0xA2C5);
    for round in 0..8 {
        let n = [1usize, 3, 16, 64][round % 4].min(xla.batch());
        let (windows, swap, states) = make_batch(&mut rng, n, w);
        let mut st_native = states.clone();
        let mut st_xla = states;
        let sig_native = native
            .step(n, &windows, &swap, &mut st_native, &params)
            .unwrap();
        let sig_xla = xla.step(n, &windows, &swap, &mut st_xla, &params).unwrap();

        assert_eq!(sig_native, sig_xla, "round {round}: signals diverge");
        for i in 0..n * STATE_LEN {
            let (a, b) = (st_native[i], st_xla[i]);
            let rel = (a - b).abs() / b.abs().max(1e-5);
            if rel >= 2e-3 {
                let pod = i / STATE_LEN;
                eprintln!(
                    "pod {pod}: window={:?} swap={} state_in(before)=?",
                    &windows[pod * w..(pod + 1) * w],
                    swap[pod],
                );
                eprintln!(
                    "native state={:?}",
                    &st_native[pod * STATE_LEN..(pod + 1) * STATE_LEN]
                );
                eprintln!(
                    "xla    state={:?}",
                    &st_xla[pod * STATE_LEN..(pod + 1) * STATE_LEN]
                );
            }
            assert!(
                rel < 2e-3,
                "round {round}: state[{i}] native={a} xla={b}"
            );
        }
    }
}

#[test]
fn xla_fleet_is_deterministic_across_calls() {
    let Ok(manifest) = Manifest::discover() else {
        eprintln!("SKIP xla_fleet_is_deterministic_across_calls: run `make artifacts` first");
        return;
    };
    let engine = Engine::cpu().unwrap();
    let mut xla = XlaFleet::from_manifest(&engine, &manifest, 64).unwrap();
    let w = xla.window();
    let mut rng = Xoshiro256::new(7);
    let (windows, swap, states) = make_batch(&mut rng, 8, w);
    let params = ArcvParams::default();

    let mut s1 = states.clone();
    let mut s2 = states;
    let g1 = xla.step(8, &windows, &swap, &mut s1, &params).unwrap();
    let g2 = xla.step(8, &windows, &swap, &mut s2, &params).unwrap();
    assert_eq!(g1, g2);
    assert_eq!(s1, s2);
}
