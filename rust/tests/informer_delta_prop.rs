//! Property suite for the delta-driven informer: replaying watch records
//! past the cursor (`ApiClient::sync`) must be indistinguishable from the
//! retained full-relist oracle (`ApiClient::sync_relist`) under
//! randomized churn — arrivals, OOMs, pressure evictions, drains,
//! uncordons, random kills, patches, restarts, requeue passes — same
//! cached views bit-for-bit, same Running/OomKilled phase indexes, same
//! transition/retire deltas, sync after sync. A third, rarely-synced
//! informer exercises cursor-safe compaction: its registered cursor pins
//! the log's compaction floor, so auto-compaction may never force a
//! relist on any registered informer, and `EventLog::revision` stays
//! monotonic throughout.
//!
//! Mirrors the `sched_queue_prop.rs` pattern (one seeded churn script,
//! incremental structure vs linear oracle, state compared pass by pass).
//!
//! The sharded-store properties extend the same oracle to vector
//! cursors: replay over a three-shard event store stays bit-identical
//! under live per-shard compaction, and a consumer stalled on one shard
//! pins only that shard's floor — the rest of the store keeps
//! compacting.

use arcv::scenario::LeakProcess;
use arcv::simkube::{
    ApiClient, Cluster, ClusterConfig, MemoryProcess, Node, ResourceSpec, Strategy, SwapDevice,
    SyncDelta,
};
use arcv::util::prop::{self, require};

/// A flat memory process (LeakProcess with zero leak): usage is constant
/// at `usage_gb` for `secs` application-seconds.
fn flat(usage_gb: f64, secs: f64) -> Box<dyn MemoryProcess> {
    Box::new(LeakProcess {
        base_gb: usage_gb,
        leak_gb_per_sec: 0.0,
        lifetime_secs: secs,
    })
}

/// A linear ramp — crosses its limit mid-run, so no-swap nodes OOM it.
fn leak(base_gb: f64, leak_per_sec: f64, secs: f64) -> Box<dyn MemoryProcess> {
    Box::new(LeakProcess {
        base_gb,
        leak_gb_per_sec: leak_per_sec,
        lifetime_secs: secs,
    })
}

fn build_cluster(caps: &[f64], strategy: Strategy) -> Cluster {
    let nodes: Vec<Node> = caps
        .iter()
        .enumerate()
        .map(|(i, &c)| Node::new(&format!("w{i}"), c, SwapDevice::disabled()))
        .collect();
    let mut c = Cluster::new(
        nodes,
        ClusterConfig {
            scheduler: strategy,
            ..ClusterConfig::default()
        },
    );
    // compaction on: cursors registered by the informers below must keep
    // every un-replayed record alive (the cursor-safety property)
    c.events.set_auto_compact(true);
    c
}

/// Compare everything the two informers maintain, bit for bit. The
/// oracle's delta always has `relisted = true`; everything else must
/// match exactly.
fn require_informers_equal(
    round: usize,
    cluster: &Cluster,
    a: &ApiClient,
    b: &ApiClient,
    da: &SyncDelta,
    db: &SyncDelta,
) -> prop::PropResult {
    if da.changed != db.changed {
        return Err(format!(
            "round {round}: changed diverged — delta {:?} vs oracle {:?}",
            da.changed, db.changed
        ));
    }
    if da.transitioned != db.transitioned {
        return Err(format!(
            "round {round}: transitions diverged — delta {:?} vs oracle {:?}",
            da.transitioned, db.transitioned
        ));
    }
    if da.retired != db.retired {
        return Err(format!(
            "round {round}: retired diverged — delta {:?} vs oracle {:?}",
            da.retired, db.retired
        ));
    }
    for id in 0..cluster.pods.len() {
        if a.cached(id) != b.cached(id) {
            return Err(format!(
                "round {round}: pod {id} cached view diverged\n  delta:  {:?}\n  oracle: {:?}",
                a.cached(id),
                b.cached(id)
            ));
        }
    }
    if a.running() != b.running() {
        return Err(format!(
            "round {round}: Running index diverged — {:?} vs {:?}",
            a.running(),
            b.running()
        ));
    }
    if a.oom_killed() != b.oom_killed() {
        return Err(format!(
            "round {round}: OomKilled index diverged — {:?} vs {:?}",
            a.oom_killed(),
            b.oom_killed()
        ));
    }
    Ok(())
}

#[test]
fn delta_replay_is_equivalent_to_full_relist_under_random_churn() {
    prop::check("informer-delta-vs-relist", 60, |g| {
        let n_nodes = g.usize(1, 4);
        let caps: Vec<f64> = (0..n_nodes).map(|_| g.f64(8.0, 48.0)).collect();
        let strategy = if g.bool(0.5) { Strategy::BestFit } else { Strategy::WorstFit };
        let mut c = build_cluster(&caps, strategy);
        // one cluster, three informers: `a` replays deltas, `b` is the
        // full-relist oracle, `lag` syncs rarely (the compaction pin)
        let mut a = ApiClient::new();
        let mut b = ApiClient::new();
        let mut lag = ApiClient::new();
        let mut created = 0usize;
        let mut last_revision = 0u64;
        for round in 0..40 {
            match g.usize(0, 8) {
                0 | 1 => {
                    // arrival: flats, best-effort balloons (pressure
                    // evictions), and tight-limit leakers (OOM kills)
                    let name = format!("p{created}");
                    let roll = g.f64(0.0, 1.0);
                    if roll < 0.15 {
                        let u = g.f64(16.0, 96.0); // balloon: evicted soon
                        c.create_pod(&name, ResourceSpec::best_effort(), flat(u, g.f64(10.0, 80.0)));
                    } else if roll < 0.40 {
                        // leaks past its limit in a handful of ticks
                        let lim = g.f64(1.0, 6.0);
                        c.create_pod(
                            &name,
                            ResourceSpec::memory_exact(lim),
                            leak(lim * 0.6, lim * g.f64(0.1, 0.4), g.f64(20.0, 80.0)),
                        );
                    } else {
                        let req = g.f64(1.0, 24.0);
                        c.create_pod(
                            &name,
                            ResourceSpec::memory_exact(req),
                            flat(req * g.f64(0.3, 0.9), g.f64(10.0, 80.0)),
                        );
                    }
                    created += 1;
                }
                2 => {
                    c.run_until(g.u64(1, 15), |_| false);
                }
                3 if created > 0 => {
                    c.kill_pod(g.usize(0, created - 1));
                }
                4 if created > 0 => {
                    c.patch_pod_memory(g.usize(0, created - 1), g.f64(1.0, 24.0));
                }
                5 if created > 0 => {
                    c.restart_pod(g.usize(0, created - 1), g.f64(1.0, 24.0));
                }
                6 => {
                    let node = g.usize(0, n_nodes - 1);
                    if g.bool(0.6) {
                        c.drain_node(node);
                    } else {
                        c.uncordon_node(node);
                    }
                }
                7 => {
                    c.schedule_pending();
                }
                _ => {}
            }
            // revisions are monotonic across pushes AND compactions
            require(c.events.revision() >= last_revision, "revision must be monotonic")?;
            last_revision = c.events.revision();
            if g.bool(0.7) {
                let da = a.sync(&mut c);
                let db = b.sync_relist(&mut c);
                require_informers_equal(round, &c, &a, &b, &da, &db)?;
            }
            if g.bool(0.15) {
                // the laggard catches up after an arbitrary backlog; its
                // registered cursor pinned every record it needed
                let dl = lag.sync(&mut c);
                if lag.informer_stats().syncs > 1 && dl.relisted {
                    return Err(format!(
                        "round {round}: lagging registered informer was forced to relist \
                         (compaction passed its cursor)"
                    ));
                }
            }
        }
        // settle: final syncs, then full three-way comparison
        c.run_until(5, |_| false);
        let da = a.sync(&mut c);
        let db = b.sync_relist(&mut c);
        require_informers_equal(99, &c, &a, &b, &da, &db)?;
        lag.sync(&mut c);
        for id in 0..c.pods.len() {
            if lag.cached(id) != b.cached(id) {
                return Err(format!("laggard pod {id} view diverged after catch-up"));
            }
        }
        require(lag.running() == b.running(), "laggard Running index diverged")?;
        require(lag.oom_killed() == b.oom_killed(), "laggard OomKilled index diverged")?;
        // the delta informer LISTed once and replayed ever after, even
        // with live compaction
        let stats = a.informer_stats();
        require(stats.relists == 1, "delta informer must not relist after the LIST")?;
        // compaction actually ran when there was enough history (both
        // fast informers at head + laggard eventually caught up)
        require(
            c.events.first_revision() <= c.events.revision(),
            "floor can never pass the head",
        )?;
        Ok(())
    });
}

#[test]
fn compaction_keeps_long_runs_bounded_without_losing_deltas() {
    // a long quiet grind with steady churn: two synced informers let the
    // log compact continuously; the informer must keep producing exact
    // deltas off the shrinking log
    let mut c = build_cluster(&[32.0, 32.0], Strategy::BestFit);
    let mut a = ApiClient::new();
    let mut b = ApiClient::new();
    // a transient informer: syncs once, then detaches — its registered
    // cursor must stop pinning the compaction floor once released
    let mut transient = ApiClient::new();
    let mut total_transitions = 0usize;
    for i in 0..200usize {
        if i == 0 {
            transient.sync(&mut c);
        }
        if i == 5 {
            transient.detach(&mut c);
        }
        let name = format!("j{i}");
        let id = c.create_pod(&name, ResourceSpec::memory_exact(2.0), flat(1.0, 6.0));
        let da = a.sync(&mut c);
        let db = b.sync_relist(&mut c);
        assert_eq!(da.changed, db.changed, "round {i} (post-create)");
        assert_eq!(da.transitioned, db.transitioned, "round {i} (post-create)");
        total_transitions += da.transitioned.len();
        c.run_until(8, |_| false); // each job completes within its round
        c.schedule_pending();
        let da = a.sync(&mut c);
        let db = b.sync_relist(&mut c);
        assert_eq!(da.changed, db.changed, "round {i}");
        assert_eq!(da.transitioned, db.transitioned, "round {i}");
        assert!(
            da.retired.contains(&id),
            "round {i}: the completed job must retire through the delta"
        );
        total_transitions += da.transitioned.len();
    }
    assert!(total_transitions >= 400, "creates + completions must all surface");
    // the log was compacted (both cursors ride the head), yet revisions
    // kept counting the whole stream
    assert!(
        (c.events.retained_len() as u64) < c.events.revision(),
        "retained {} of {} revisions — compaction never ran",
        c.events.retained_len(),
        c.events.revision()
    );
    assert_eq!(a.informer_stats().relists, 1);
}

/// Build a 6-node cluster sharded into three 2-node event shards.
fn build_sharded_cluster(cap: f64) -> Cluster {
    let nodes: Vec<Node> = (0..6)
        .map(|i| Node::new(&format!("w{i}"), cap, SwapDevice::disabled()))
        .collect();
    let mut c = Cluster::new(nodes, ClusterConfig::default());
    c.set_event_shards(vec![0, 0, 1, 1, 2, 2]);
    c.events.set_auto_compact(true);
    c
}

#[test]
fn vector_cursor_replay_matches_oracle_under_sharded_compaction() {
    // the sharded-store version of the delta-vs-relist property, plus the
    // vector-cursor compaction claim: a laggard whose backlog lives on
    // shard 0 pins ONLY shard 0's floor — the other shards keep
    // compacting underneath it.
    prop::check("informer-vector-cursor", 40, |g| {
        let mut c = build_sharded_cluster(32.0);
        let mut a = ApiClient::new();
        let mut b = ApiClient::new();
        let mut lag = ApiClient::new();
        lag.sync(&mut c); // register the laggard's vector cursor at rev 0
        let mut created = 0usize;
        for round in 0..40 {
            match g.usize(0, 6) {
                0 | 1 => {
                    // arrival mix as in the unsharded property: leakers
                    // (OOM kills) and flats, spread across all shards by
                    // the scheduler
                    let name = format!("p{created}");
                    if g.bool(0.3) {
                        let lim = g.f64(1.0, 6.0);
                        c.create_pod(
                            &name,
                            ResourceSpec::memory_exact(lim),
                            leak(lim * 0.6, lim * g.f64(0.1, 0.4), g.f64(20.0, 80.0)),
                        );
                    } else {
                        let req = g.f64(1.0, 12.0);
                        c.create_pod(
                            &name,
                            ResourceSpec::memory_exact(req),
                            flat(req * g.f64(0.3, 0.9), g.f64(10.0, 80.0)),
                        );
                    }
                    created += 1;
                }
                2 => c.run_until(g.u64(1, 15), |_| false),
                3 if created > 0 => c.kill_pod(g.usize(0, created - 1)),
                4 if created > 0 => {
                    c.patch_pod_memory(g.usize(0, created - 1), g.f64(1.0, 12.0));
                }
                5 if created > 0 => {
                    c.restart_pod(g.usize(0, created - 1), g.f64(1.0, 12.0));
                }
                6 => {
                    c.schedule_pending();
                }
                _ => {}
            }
            if g.bool(0.7) {
                let da = a.sync(&mut c);
                let db = b.sync_relist(&mut c);
                require_informers_equal(round, &c, &a, &b, &da, &db)?;
            }
        }
        // settle, then the laggard catches up: registered vector cursors
        // pinned every shard's floor at rev 0, so no relist
        c.run_until(5, |_| false);
        let da = a.sync(&mut c);
        let db = b.sync_relist(&mut c);
        require_informers_equal(99, &c, &a, &b, &da, &db)?;
        let dl = lag.sync(&mut c);
        require(
            !dl.relisted,
            "registered laggard must replay, never relist (its cursor pins every shard floor)",
        )?;
        for id in 0..c.pods.len() {
            if lag.cached(id) != b.cached(id) {
                return Err(format!("laggard pod {id} view diverged after catch-up"));
            }
        }
        require(a.informer_stats().relists == 1, "delta informer relists only the LIST")?;
        Ok(())
    });
}

#[test]
fn laggard_pinned_on_one_shard_does_not_block_other_shards_compaction() {
    // the per-shard floor claim, driven through real cluster churn: a
    // consumer whose replay is frozen on shard 0 (a vector cursor held at
    // its shard-0 component while riding the other heads — the shape a
    // partition-stalled shard consumer produces) must pin ONLY shard 0.
    // With the old scalar cursor this pinned the whole log: nothing
    // anywhere could compact past the laggard's one stuck revision.
    let mut c = build_sharded_cluster(16.0);
    let mut fast = ApiClient::new();
    fast.sync(&mut c); // fast informer rides every head
    let slot = c.events.register_cursor();
    // seed a short shard-0 backlog the frozen cursor never replays: empty
    // equal nodes tie-break to the first index, so 2 GB pods pack node 0
    for i in 0..4 {
        let id = c.create_pod(&format!("s0-{i}"), ResourceSpec::memory_exact(2.0), flat(1.0, 4.0));
        assert_eq!(c.pods[id].node, Some(0), "setup: pod must land on node 0 / shard 0");
    }
    c.run_until(6, |_| false); // completions: more shard-0 records
    fast.sync(&mut c);
    // long-lived fillers leave exact-fit slack only on nodes 2-5, so the
    // churn below deterministically lands on shards 1 and 2: 3 GB fits
    // only node 2/3 slack, 4 GB only the empty tail nodes
    for (name, gb, want) in
        [("fill0", 14.0, 0usize), ("fill1", 14.0, 1), ("fill2", 13.0, 2), ("fill3", 13.0, 3)]
    {
        let id = c.create_pod(name, ResourceSpec::memory_exact(gb), flat(6.0, 1e9));
        assert_eq!(c.pods[id].node, Some(want), "setup: filler placement");
    }
    fast.sync(&mut c);
    let frozen0 = 1; // replayed through revision 1 on shard 0, then stalled
    let heads = c.events.heads();
    assert!(heads[0] > frozen0, "setup: shard 0 must hold a backlog past the frozen component");
    c.events.advance_cursor_vec(slot, &[frozen0, heads[1], heads[2]]);
    let floors_before = c.events.shard_first_revisions();
    // churn shards 1-2 far past the compaction threshold; the frozen
    // consumer keeps riding shards 1-2 but never moves on shard 0
    for i in 0..150 {
        let a = c.create_pod(&format!("s1-{i}"), ResourceSpec::memory_exact(3.0), flat(1.5, 3.0));
        assert_eq!(c.events.shard_of(c.pods[a].node.unwrap()), 1, "churn A must hit shard 1");
        let b = c.create_pod(&format!("s2-{i}"), ResourceSpec::memory_exact(4.0), flat(1.5, 3.0));
        assert_eq!(c.events.shard_of(c.pods[b].node.unwrap()), 2, "churn B must hit shard 2");
        c.run_until(5, |_| false); // both complete; capacity retires
        fast.sync(&mut c);
        let h = c.events.heads();
        c.events.advance_cursor_vec(slot, &[frozen0, h[1], h[2]]);
    }
    let floors_after = c.events.shard_first_revisions();
    // shard 0's floor can reach the frozen component but never pass it —
    // the stalled consumer's suffix is intact and replayable
    assert!(
        floors_after[0] <= frozen0,
        "shard 0 compacted past the frozen cursor ({} > {frozen0})",
        floors_after[0]
    );
    let backlog = c
        .events
        .shard(0)
        .since(frozen0)
        .expect("the frozen consumer's shard-0 suffix must stay replayable");
    assert!(!backlog.is_empty(), "setup produced no shard-0 backlog");
    // the other shards compacted right past the laggard's stall point
    assert!(
        floors_after[1] > floors_before[1] && floors_after[2] > floors_before[2],
        "shards 1 and 2 must keep compacting ({floors_before:?} -> {floors_after:?})"
    );
}
