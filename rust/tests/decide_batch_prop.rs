//! Property suite for the batched decision plane: randomized fleets of
//! per-pod kernels evaluated through `PerPodAdapter::decide_batch` (SoA
//! staging, column-wise signal/forecast passes, per-node groups,
//! deterministic ascending-pod-id merge) must produce exactly the action
//! stream of the scalar `decide` loop — bit for bit, every `Resize` f64
//! included — under randomized windows, parameters, policy mixes, node
//! assignments, partial presence, observe-row ordering, and worker
//! counts. A fixed large-fleet case additionally pins the parallel
//! evaluation path (rows past `DECIDE_ROWS_PER_WORKER`) against both the
//! serial batch and the scalar reference.

use arcv::policy::arcv::{ArcvParams, ArcvPolicy};
use arcv::policy::fixed::FixedPolicy;
use arcv::policy::vpa::VpaSimPolicy;
use arcv::policy::{DecisionBatch, NodePolicy, PerPodAdapter, PodAction, VerticalPolicy};
use arcv::simkube::{PodId, PodPhase, PodView, QosClass, Sample};
use arcv::util::prop::{self, require};
use std::collections::BTreeMap;

fn view(id: PodId, node: Option<usize>, limit_gb: f64, started_at: Option<u64>) -> PodView {
    PodView {
        id,
        name: format!("p{id}"),
        phase: PodPhase::Running,
        qos: QosClass::Burstable,
        node,
        resource_version: 1,
        spec_memory_gb: Some(limit_gb),
        effective_limit_gb: limit_gb,
        restarts: 0,
        started_at,
    }
}

/// Two bit-identical boxed kernels of a random kind: ARC-V (the staged
/// column-wise path) most of the time, VPA-sim (the scalar fallback plan
/// inside a mixed batch) and fixed (no decisions at all) as minorities.
fn twin_kernels(
    g: &mut prop::Gen,
    init_gb: f64,
) -> (Box<dyn VerticalPolicy>, Box<dyn VerticalPolicy>) {
    match g.usize(0, 9) {
        0..=6 => {
            let p = ArcvParams {
                window: g.usize(3, 14),
                decision_interval_secs: g.u64(4, 40),
                init_phase_secs: g.u64(0, 30),
                stability: g.f64(0.005, 0.08),
                horizon_samples: g.usize(2, 16) as f64,
                ..ArcvParams::default()
            };
            (Box::new(ArcvPolicy::new(init_gb, p)), Box::new(ArcvPolicy::new(init_gb, p)))
        }
        7 | 8 => (Box::new(VpaSimPolicy::new(init_gb)), Box::new(VpaSimPolicy::new(init_gb))),
        _ => (Box::new(FixedPolicy::new(init_gb)), Box::new(FixedPolicy::new(init_gb))),
    }
}

#[test]
fn batched_decide_matches_scalar_action_for_action() {
    prop::check("decide-batch-vs-scalar", 60, |g| {
        // a fleet with pod-id gaps (merge walks must not assume density)
        let n = g.usize(1, 20);
        let mut ids: Vec<PodId> = Vec::new();
        let mut next = 0usize;
        for _ in 0..n {
            next += g.usize(1, 4);
            ids.push(next);
        }
        let n_nodes = g.usize(1, 4);
        let mut scalar = PerPodAdapter::new(); // the reference plane
        let mut batched = PerPodAdapter::new();
        batched.set_decide_threads(*g.pick(&[0usize, 1, 2, 4]));
        let mut limits: BTreeMap<PodId, f64> = BTreeMap::new();
        for &id in &ids {
            let init = g.f64(1.0, 16.0);
            limits.insert(id, init);
            let (pa, pb) = twin_kernels(g, init);
            scalar.manage(id, pa);
            batched.manage(id, pb);
        }
        // fixed node assignment per pod (a few left unbound: the
        // usize::MAX bucket must merge like any other)
        let nodes: Vec<Option<usize>> = ids
            .iter()
            .map(|_| g.bool(0.9).then(|| g.usize(0, n_nodes - 1)))
            .collect();
        let grid = g.u64(2, 7);
        let horizon = g.u64(30, 150);
        for now in 1..=horizon {
            if now % grid == 0 {
                // identical samples into both planes — through the batch
                // surface on `batched`, sometimes in reversed row order to
                // exercise the out-of-order observe fallback (observe
                // order across DISTINCT pods never touches per-pod state,
                // so the twins stay comparable)
                let mut rows: Vec<(PodId, Sample)> = Vec::new();
                for &id in &ids {
                    if g.bool(0.85) {
                        let u = g.f64(0.2, 20.0);
                        let sw = if g.bool(0.2) { g.f64(0.0, 2.0) } else { 0.0 };
                        rows.push((
                            id,
                            Sample {
                                time: now,
                                usage_gb: u,
                                rss_gb: u - sw,
                                swap_gb: sw,
                                limit_gb: limits[&id],
                            },
                        ));
                    }
                }
                if g.bool(0.2) {
                    rows.reverse();
                }
                let mut batch = DecisionBatch::new(now);
                for (id, s) in &rows {
                    scalar.observe(now, *id, s);
                    batch.push_observe(*id, s);
                }
                if batch.obs_len() > 0 {
                    batched.observe_batch(now, &batch);
                }
            }
            if g.bool(0.5) {
                // a decision wake over a random present subset
                let views: Vec<PodView> = ids
                    .iter()
                    .zip(&nodes)
                    .filter(|_| g.bool(0.9))
                    .map(|(&id, &node)| view(id, node, limits[&id], Some(0)))
                    .collect();
                let refs: Vec<&PodView> = views.iter().collect();
                let mut batch = DecisionBatch::new(now);
                for v in &views {
                    batch.push_decide(v, None);
                }
                let acts_a: Vec<PodAction> = scalar.decide(now, &refs);
                let acts_b = batched.decide_batch(now, &batch);
                if acts_a != acts_b {
                    return Err(format!("t={now}: scalar {acts_a:?} vs batched {acts_b:?}"));
                }
            }
        }
        // the kernels themselves must have marched in lockstep, not just
        // the emitted actions: final recommendations bit-identical
        for &id in &ids {
            let ra = scalar.policy_of(id).and_then(|p| p.recommendation_gb());
            let rb = batched.policy_of(id).and_then(|p| p.recommendation_gb());
            require(
                ra.map(f64::to_bits) == rb.map(f64::to_bits),
                "final recommendations diverged between planes",
            )?;
        }
        Ok(())
    });
}

#[test]
fn parallel_batch_matches_serial_batch_and_scalar_at_scale() {
    // enough staged rows to clear DECIDE_ROWS_PER_WORKER, so auto worker
    // selection actually engages on multi-core machines — the property
    // above can't reach this regime at its fleet sizes
    const PODS: usize = 2304;
    const NODES: usize = 8;
    let params = ArcvParams {
        window: 4,
        decision_interval_secs: 5,
        init_phase_secs: 0,
        ..ArcvParams::default()
    };
    let build = |threads: usize| {
        let mut ad = PerPodAdapter::new();
        for id in 0..PODS {
            ad.manage(id, Box::new(ArcvPolicy::new(8.0, params)));
        }
        ad.set_decide_threads(threads);
        ad
    };
    let mut scalar = build(1);
    let mut serial = build(1);
    let mut auto = build(0);
    let mut all_actions = 0usize;
    let mut auto_workers = 0usize;
    for round in 0..10u64 {
        // one flat-ish observation per kernel (tiny per-pod offset keeps
        // every row distinct), then a decision wake one tick later
        let now_obs = (round + 1) * 5;
        let mut obs = DecisionBatch::new(now_obs);
        for id in 0..PODS {
            let u = 2.0 + id as f64 * 1e-4;
            let s = Sample {
                time: now_obs,
                usage_gb: u,
                rss_gb: u,
                swap_gb: 0.0,
                limit_gb: 8.0,
            };
            scalar.observe(now_obs, id, &s);
            serial.observe(now_obs, id, &s);
            obs.push_observe(id, &s);
        }
        auto.observe_batch(now_obs, &obs);

        let now = now_obs + 1;
        let mut views = Vec::with_capacity(PODS);
        for id in 0..PODS {
            views.push(view(id, Some(id % NODES), 8.0, Some(0)));
        }
        let refs: Vec<&PodView> = views.iter().collect();
        let mut batch = DecisionBatch::new(now);
        for v in &views {
            batch.push_decide(v, None);
        }
        let acts_scalar = scalar.decide(now, &refs);
        let acts_serial = serial.decide_batch(now, &batch);
        let acts_auto = auto.decide_batch(now, &batch);
        assert_eq!(acts_scalar, acts_serial, "round {round}: serial batch diverged");
        assert_eq!(acts_scalar, acts_auto, "round {round}: parallel batch diverged");
        assert_eq!(serial.last_decide_workers(), 1, "threads=1 must stay serial");
        all_actions += acts_scalar.len();
        auto_workers = auto_workers.max(auto.last_decide_workers());
    }
    // potency: a flat fleet parked at 4x its need must shrink under the
    // decayed-stable path — a silent run would vacuously pass the above
    assert!(all_actions > 0, "the over-provisioned fleet never resized");
    let avail = std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1);
    if avail >= 2 {
        assert!(
            auto_workers >= 2,
            "auto worker selection never engaged at {PODS} rows ({auto_workers} workers)"
        );
    }
}
