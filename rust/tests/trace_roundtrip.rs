//! Integration suite for the loadgen trace format: capture → serialize →
//! parse → replay must be the identity, across random seeds, arrival
//! processes, policies, and kernel modes — plus the failure modes a
//! versioned on-disk format owes its readers (malformed lines, version
//! mismatch, truncation).

use arcv::harness::SwapKind;
use arcv::loadgen::{Trace, TraceError, TRACE_VERSION};
use arcv::policy::arcv::ArcvParams;
use arcv::scenario::{run_scenario_mode, Arrivals, ScenarioPolicy, ScenarioSpec, WorkloadMix};
use arcv::simkube::KernelMode;
use arcv::util::prop::{check, require};
use arcv::workloads::AppId;

/// Capture → parse → replay pins the EventLog and ScenarioOutcome
/// bit-for-bit: in the capturing kernel mode AND an independently drawn
/// one (the equivalence contract extends to replays).
#[test]
fn roundtrip_replay_is_bit_identical_across_seeds_and_modes() {
    let apps = [AppId::Amr, AppId::Cm1, AppId::Sputnipic];
    let modes = [
        KernelMode::Lockstep,
        KernelMode::EventDriven,
        KernelMode::Sharded { threads: 2 },
    ];
    check("trace-roundtrip-replay", 10, |g| {
        let seed = g.u64(1, 1 << 40);
        let jobs = g.usize(2, 5);
        let arrivals = match g.usize(0, 2) {
            0 => Arrivals::Backlog,
            1 => Arrivals::Poisson { rate_per_min: g.f64(3.0, 12.0) },
            _ => Arrivals::Bursty {
                period_secs: g.u64(30, 90),
                burst: g.usize(1, 3),
            },
        };
        let mut mix_apps = vec![*g.pick(&apps)];
        let extra = *g.pick(&apps);
        if g.bool(0.5) && !mix_apps.contains(&extra) {
            mix_apps.push(extra);
        }
        let policy = if g.bool(0.5) {
            ScenarioPolicy::Fixed
        } else {
            ScenarioPolicy::Arcv(ArcvParams::default())
        };
        let spec = ScenarioSpec::new("prop-trace")
            .pool("n", 2, 24.0, SwapKind::Hdd(8.0))
            .mix(WorkloadMix::uniform(&mix_apps))
            .arrivals(arrivals)
            .jobs(jobs)
            .max_ticks(20_000);

        let capture_mode = *g.pick(&modes);
        let run = run_scenario_mode(&spec, policy, seed, capture_mode);
        let trace = Trace::capture(&spec, &policy, seed, &run);
        let parsed = Trace::parse(&trace.to_lines()).map_err(|e| e.to_string())?;
        require(parsed == trace, "parse(to_lines(trace)) must be the identity")?;
        require(
            parsed.header.seed == seed && parsed.header.jobs == jobs,
            "header carries the run identity",
        )?;

        let replay_spec = parsed.replay_spec(&spec).map_err(|e| e.to_string())?;
        let other_mode = *g.pick(&modes);
        for mode in [capture_mode, other_mode] {
            let replay = run_scenario_mode(&replay_spec, policy, parsed.header.seed, mode);
            parsed.verify_replay(&replay)?;
            require(
                replay.outcome == run.outcome,
                "replayed ScenarioOutcome must be bit-identical",
            )?;
        }
        Ok(())
    });
}

fn small_capture() -> Trace {
    let spec = ScenarioSpec::new("err-trace")
        .pool("n", 1, 24.0, SwapKind::Hdd(8.0))
        .mix(WorkloadMix::uniform(&[AppId::Amr]))
        .arrivals(Arrivals::Backlog)
        .jobs(2)
        .max_ticks(5_000);
    let policy = ScenarioPolicy::Fixed;
    let run = run_scenario_mode(&spec, policy, 9, KernelMode::EventDriven);
    Trace::capture(&spec, &policy, 9, &run)
}

#[test]
fn version_mismatch_is_a_typed_error() {
    let trace = small_capture();
    let bumped = trace.to_lines().replace("\"version\":1", "\"version\":2");
    assert_eq!(
        Trace::parse(&bumped).unwrap_err(),
        TraceError::VersionMismatch { found: 2, expected: TRACE_VERSION }
    );
}

#[test]
fn malformed_files_name_the_offending_line() {
    let trace = small_capture();
    let good = trace.to_lines();

    // an unknown watch-record type is a format break, not a skip
    let unknown = good.replace("pod_scheduled", "pod_teleported");
    assert!(matches!(
        Trace::parse(&unknown).unwrap_err(),
        TraceError::Malformed { .. }
    ));

    // stripping the header leaves an unreadable file
    let headerless: String = good.lines().skip(1).collect::<Vec<_>>().join("\n");
    assert_eq!(Trace::parse(&headerless).unwrap_err(), TraceError::MissingHeader);

    // truncation trips the header's integrity counts (line 0 = whole-file)
    let lines: Vec<&str> = good.lines().collect();
    let truncated = lines[..lines.len() - 1].join("\n");
    assert!(matches!(
        Trace::parse(&truncated).unwrap_err(),
        TraceError::Malformed { line: 0, .. }
    ));

    // a corrupted json body reports its 1-based line
    let mut corrupt: Vec<String> = good.lines().map(String::from).collect();
    corrupt[1] = "0 {broken".to_string();
    assert!(matches!(
        Trace::parse(&corrupt.join("\n")).unwrap_err(),
        TraceError::Malformed { line: 2, .. }
    ));
}
