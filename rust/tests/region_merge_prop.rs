//! Property suite for parallel stepping regions: a sharded event-driven
//! cluster whose every advance runs through `Cluster::step_region` —
//! thrashing pods keep the nodes hot, so there is nothing to coast — must
//! be indistinguishable from the lockstep 1 s reference under randomized
//! churn (kills, resize patches, restarts, drains, requeues) and **live
//! log compaction**: auto-compaction enabled with an advancing informer
//! cursor on both logs, so shard-buffer merges land on a log whose base
//! revision keeps moving. Same events, same revisions, same pod state,
//! at a randomized worker count per case.

use arcv::scenario::LeakProcess;
use arcv::simkube::{
    AdvanceOpts, Cluster, ClusterConfig, MemoryProcess, Node, ResourceSpec, SwapDevice,
};
use arcv::util::prop::{self, require};
use std::sync::atomic::{AtomicU64, Ordering};

/// A flat memory process (LeakProcess with zero leak): usage is constant
/// at `usage_gb` for `secs` application-seconds.
fn flat(usage_gb: f64, secs: f64) -> Box<dyn MemoryProcess> {
    Box::new(LeakProcess {
        base_gb: usage_gb,
        leak_gb_per_sec: 0.0,
        lifetime_secs: secs,
    })
}

fn build_cluster(caps: &[f64], swapped: &[bool]) -> Cluster {
    let nodes: Vec<Node> = caps
        .iter()
        .zip(swapped)
        .enumerate()
        .map(|(i, (&c, &sw))| {
            let dev = if sw { SwapDevice::hdd(c) } else { SwapDevice::disabled() };
            Node::new(&format!("w{i}"), c, dev)
        })
        .collect();
    Cluster::new(nodes, ClusterConfig::default())
}

#[test]
fn parallel_regions_match_lockstep_under_churn_and_live_compaction() {
    // counted across cases: the workload must actually drive the region
    // path, not accidentally coast past it
    let regions = AtomicU64::new(0);
    prop::check("parallel-regions-vs-lockstep", 60, |g| {
        let n_nodes = g.usize(2, 5);
        let caps: Vec<f64> = (0..n_nodes).map(|_| g.f64(12.0, 32.0)).collect();
        let swapped: Vec<bool> = (0..n_nodes).map(|_| g.bool(0.7)).collect();
        let shards = *g.pick(&[1usize, 2, 4]);
        // cluster A is the lockstep reference; cluster B advances through
        // sharded stepping regions. Both logs compact live behind a
        // replaying cursor, and both stores carry the SAME randomized
        // event-shard map — region workers append straight into shards,
        // lockstep appends serially, and the streams must still agree.
        let eshards = g.usize(1, 3).min(n_nodes);
        let emap: Vec<usize> = (0..n_nodes).map(|n| n % eshards).collect();
        let mut a = build_cluster(&caps, &swapped);
        let mut b = build_cluster(&caps, &swapped);
        a.set_event_shards(emap.clone());
        b.set_event_shards(emap);
        let ca = a.events.register_cursor();
        let cb = b.events.register_cursor();
        a.events.set_auto_compact(true);
        b.events.set_auto_compact(true);
        let opts = AdvanceOpts { event_driven: true, sample_metrics: true, shards };
        let mut created = 0usize;
        for round in 0..30 {
            match g.usize(0, 5) {
                0 | 1 => {
                    // arrival: thrashers (flat usage parked above the
                    // limit: permanent swap residency or an OOM on
                    // swapless nodes — either way the node stays hot) mixed
                    // with calm under-limit pods
                    let name = format!("p{created}");
                    let req = g.f64(2.0, 8.0);
                    let usage = if g.bool(0.5) { req * g.f64(1.05, 1.4) } else { req * 0.6 };
                    let secs = g.f64(20.0, 120.0);
                    a.create_pod(&name, ResourceSpec::memory_exact(req), flat(usage, secs));
                    b.create_pod(&name, ResourceSpec::memory_exact(req), flat(usage, secs));
                    created += 1;
                }
                2 if created > 0 => {
                    let id = g.usize(0, created - 1);
                    a.kill_pod(id);
                    b.kill_pod(id);
                }
                3 if created > 0 => {
                    // resize storm: random patches keep `pending_resize`
                    // set, defeating the per-pod quiescence proof
                    let id = g.usize(0, created - 1);
                    let gb = g.f64(1.0, 10.0);
                    a.patch_pod_memory(id, gb);
                    b.patch_pod_memory(id, gb);
                }
                4 if created > 0 => {
                    let id = g.usize(0, created - 1);
                    let gb = g.f64(2.0, 8.0);
                    a.restart_pod(id, gb);
                    b.restart_pod(id, gb);
                }
                5 => {
                    let node = g.usize(0, n_nodes - 1);
                    if g.bool(0.6) {
                        a.drain_node(node);
                        b.drain_node(node);
                    } else {
                        a.uncordon_node(node);
                        b.uncordon_node(node);
                    }
                }
                _ => {}
            }
            if g.bool(0.7) {
                let pa = a.schedule_pending();
                let pb = b.schedule_pending();
                require(pa == pb, "requeue passes place identically")?;
            }
            // advance both to the same tick: A per second, B through
            // regions (interrupts just re-enter the loop, like the kernel)
            let ticks = g.u64(3, 25);
            a.run_until(ticks, |_| false);
            while b.now < a.now {
                b.advance_to(a.now, opts);
            }
            if a.now != b.now {
                return Err(format!("round {round}: clocks diverged {} vs {}", a.now, b.now));
            }
            let (ra, rb) = (a.events.revision(), b.events.revision());
            if ra != rb {
                return Err(format!("round {round}: revisions diverged {ra} vs {rb}"));
            }
            if g.bool(0.8) {
                // the informer replays through the head: identical cursor
                // motion, so compaction (if it fires) fires identically —
                // per shard, since the cursor is a vector
                let (ha, hb) = (a.events.heads(), b.events.heads());
                require(ha == hb, "per-shard heads must match")?;
                a.events.advance_cursor_vec(ca, &ha);
                b.events.advance_cursor_vec(cb, &hb);
            }
        }
        require(
            a.events.first_revision() == b.events.first_revision(),
            "compaction floors must match",
        )?;
        require(
            a.events.shard_first_revisions() == b.events.shard_first_revisions(),
            "per-shard compaction floors must match",
        )?;
        require(
            a.events.snapshot() == b.events.snapshot(),
            "retained event logs must be identical",
        )?;
        for id in 0..a.pods.len() {
            let (pa, pb) = (a.pod(id), b.pod(id));
            if pa.phase != pb.phase
                || pa.node != pb.node
                || pa.progress_secs != pb.progress_secs
                || pa.usage.swap_gb != pb.usage.swap_gb
                || pa.provisioned_gb_secs != pb.provisioned_gb_secs
                || pa.used_gb_secs != pb.used_gb_secs
            {
                return Err(format!(
                    "pod {id}: {:?}@{:?} vs {:?}@{:?}",
                    pa.phase, pa.node, pb.phase, pb.node
                ));
            }
        }
        regions.fetch_add(b.coast_stats.regions_entered, Ordering::Relaxed);
        Ok(())
    });
    assert!(
        regions.load(Ordering::Relaxed) > 0,
        "the churn workload never exercised a stepping region"
    );
}
