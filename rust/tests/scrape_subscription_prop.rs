//! Property suite for the subscription metrics plane: sampling only the
//! subscribed pods, each at its own cadence, must be *losslessly sparse* —
//! every sample the subscribed sampler records is bit-identical to what a
//! full every-tick sampler records for the same pod at the same tick, and
//! it records nothing else. The oracle is a mirrored cluster driven by the
//! identical churn script with legacy full sampling at a 1 s grid (a
//! superset of every possible cadence), so any cadence's due ticks are a
//! subset of the oracle's samples.
//!
//! Also pins that the observation plane is *inert*: installing, mutating,
//! or emptying the subscription set never changes pod state — the two
//! clusters stay bit-identical in phase and usage throughout.
//!
//! Mirrors the `informer_delta_prop.rs` pattern (one seeded churn script,
//! sparse structure vs dense oracle, state compared tick by tick).

use arcv::scenario::LeakProcess;
use arcv::simkube::{
    Cluster, ClusterConfig, MemoryProcess, Node, ResourceSpec, ScrapeCadence, SharedInformer,
    SubscriptionSet, SwapDevice,
};
use arcv::util::prop::{self, require};

/// A flat memory process (LeakProcess with zero leak).
fn flat(usage_gb: f64, secs: f64) -> Box<dyn MemoryProcess> {
    Box::new(LeakProcess {
        base_gb: usage_gb,
        leak_gb_per_sec: 0.0,
        lifetime_secs: secs,
    })
}

/// A linear ramp — crosses its limit mid-run, so no-swap nodes OOM it.
fn leak(base_gb: f64, leak_per_sec: f64, secs: f64) -> Box<dyn MemoryProcess> {
    Box::new(LeakProcess {
        base_gb,
        leak_gb_per_sec: leak_per_sec,
        lifetime_secs: secs,
    })
}

fn build_cluster(cap: f64) -> Cluster {
    Cluster::new(
        vec![Node::new("w0", cap, SwapDevice::disabled())],
        ClusterConfig::default(),
    )
}

#[test]
fn subscribed_sampler_matches_full_sampler_restricted_to_due_ticks() {
    prop::check("scrape-subscriptions-vs-full", 40, |g| {
        let cap = g.f64(32.0, 128.0);
        // `a` runs the subscription plane on the default 5 s grid; `b` is
        // the dense oracle — legacy full sampling, 1 s grid, so it holds a
        // fresh sample for every Running pod at every tick
        let mut a = build_cluster(cap);
        let mut b = build_cluster(cap);
        a.install_subscriptions(SubscriptionSet::new());
        b.metrics.period_secs = 1;
        let grid = a.metrics.period_secs;
        let mut subs = SubscriptionSet::new();
        let mut created = 0usize;
        for _round in 0..30 {
            match g.usize(0, 7) {
                0 | 1 => {
                    // identical arrival on both clusters
                    let name = format!("p{created}");
                    let lim = g.f64(1.0, 8.0);
                    let secs = g.f64(10.0, 90.0);
                    if g.bool(0.3) {
                        let slope = lim * g.f64(0.05, 0.3);
                        a.create_pod(&name, ResourceSpec::memory_exact(lim), leak(lim * 0.6, slope, secs));
                        b.create_pod(&name, ResourceSpec::memory_exact(lim), leak(lim * 0.6, slope, secs));
                    } else {
                        let u = lim * g.f64(0.3, 0.9);
                        a.create_pod(&name, ResourceSpec::memory_exact(lim), flat(u, secs));
                        b.create_pod(&name, ResourceSpec::memory_exact(lim), flat(u, secs));
                    }
                    created += 1;
                }
                2 if created > 0 => {
                    // (re)subscribe at a random cadence — shared grid or a
                    // private interval, including off-grid primes
                    let pod = g.usize(0, created - 1);
                    let cad = if g.bool(0.4) {
                        ScrapeCadence::Grid
                    } else {
                        ScrapeCadence::EverySecs(g.u64(1, 12))
                    };
                    subs.subscribe(pod, cad);
                    a.install_subscriptions(subs.clone());
                }
                3 if created > 0 => {
                    subs.unsubscribe(g.usize(0, created - 1));
                    a.install_subscriptions(subs.clone());
                }
                4 if created > 0 => {
                    let pod = g.usize(0, created - 1);
                    a.kill_pod(pod);
                    b.kill_pod(pod);
                }
                5 if created > 0 => {
                    let pod = g.usize(0, created - 1);
                    let gb = g.f64(1.0, 16.0);
                    a.patch_pod_memory(pod, gb);
                    b.patch_pod_memory(pod, gb);
                }
                6 if created > 0 => {
                    let pod = g.usize(0, created - 1);
                    let gb = g.f64(1.0, 16.0);
                    a.restart_pod(pod, gb);
                    b.restart_pod(pod, gb);
                }
                _ => {}
            }
            // step both clusters in lockstep and compare tick by tick
            for _ in 0..g.u64(1, 10) {
                a.step();
                b.step();
                require(a.now == b.now, "mirrored clocks diverged")?;
                let t = a.now;
                for pod in 0..created {
                    // the observation plane must be inert: pod state is
                    // bit-identical whether or not anyone subscribes
                    if a.pod(pod).phase != b.pod(pod).phase {
                        return Err(format!(
                            "t={t}: pod {pod} phase diverged — {:?} vs {:?}",
                            a.pod(pod).phase,
                            b.pod(pod).phase
                        ));
                    }
                    require(
                        a.pod(pod).usage.usage_gb == b.pod(pod).usage.usage_gb,
                        "pod usage diverged between mirrored clusters",
                    )?;
                    let due = subs.due(pod, t, grid) && a.pod(pod).is_running();
                    let last_a = a.metrics.last(pod);
                    if due {
                        let Some(sa) = last_a else {
                            return Err(format!("t={t}: pod {pod} due but never sampled"));
                        };
                        require(sa.time == t, "due pod's sample not stamped this tick")?;
                        let Some(sb) = b.metrics.last(pod) else {
                            return Err(format!("t={t}: oracle has no sample for pod {pod}"));
                        };
                        require(sb.time == t, "oracle must sample every Running pod tick")?;
                        if sa != sb {
                            return Err(format!(
                                "t={t}: pod {pod} sample diverged — {sa:?} vs {sb:?}"
                            ));
                        }
                    } else if let Some(sa) = last_a {
                        // not subscribed+due+Running: the sparse sampler
                        // must NOT have recorded anything this tick
                        require(
                            sa.time != t,
                            "sampler recorded a pod that was not subscribed and due",
                        )?;
                    }
                }
            }
        }
        // the plane's own ledger is consistent with what we observed
        let s = a.scrape_stats();
        require(
            s.samples_recorded <= s.pods_visited,
            "recorded samples cannot exceed visits",
        )?;
        require(
            a.metrics.live_series() <= created,
            "live series bounded by created pods",
        )?;
        Ok(())
    });
}

/// Two consumers on one shared informer plane: the plane replays each
/// watch record once no matter how many consumers ride it, while each
/// consumer is credited the full stream — the saving the plane exists for.
#[test]
fn shared_informer_replays_the_stream_once_for_all_consumers() {
    prop::check("shared-informer-replay-once", 25, |g| {
        let mut c = build_cluster(g.f64(32.0, 96.0));
        let mut plane = SharedInformer::new();
        let first = plane.register();
        let second = plane.register();
        let mut created = 0usize;
        for _round in 0..20 {
            match g.usize(0, 4) {
                0 | 1 => {
                    let lim = g.f64(1.0, 6.0);
                    c.create_pod(
                        &format!("p{created}"),
                        ResourceSpec::memory_exact(lim),
                        flat(lim * g.f64(0.3, 0.8), g.f64(5.0, 40.0)),
                    );
                    created += 1;
                }
                2 if created > 0 => {
                    c.patch_pod_memory(g.usize(0, created - 1), g.f64(1.0, 8.0));
                }
                3 if created > 0 => {
                    c.kill_pod(g.usize(0, created - 1));
                }
                _ => {
                    c.run_until(g.u64(1, 10), |_| false);
                }
            }
            // one driver syncs physically; the other rides the delta
            plane.sync(&mut c, first);
            plane.credit(&c, second);
        }
        let head = c.events.revision();
        // physical replay is bounded by the stream itself (each record
        // once), while per-consumer credit shows the 2x a pair of private
        // informers would have paid
        require(
            plane.stats().events_replayed <= head,
            "plane replayed records more than once",
        )?;
        require(
            plane.replays(first) == plane.replays(second),
            "both consumers must be credited the same stream",
        )?;
        require(
            plane.total_replays() == 2 * plane.replays(first),
            "total credit is the sum over consumers",
        )?;
        require(plane.consumer_count() == 2, "both consumers live")?;
        Ok(())
    });
}
