//! Property-based tests over the coordinator's invariants (the proptest
//! role in this offline build — see util::prop). Each property runs a few
//! hundred seeded random cases.

use arcv::policy::arcv::{detect, ArcvParams, PodState, Signal, State};
use arcv::policy::vpa::VpaSimPolicy;
use arcv::policy::VerticalPolicy;
use arcv::simkube::cluster::Cluster;
use arcv::simkube::node::Node;
use arcv::simkube::pod::MemoryProcess;
use arcv::simkube::resources::ResourceSpec;
use arcv::simkube::scheduler::{Scheduler, Strategy};
use arcv::simkube::swap::SwapDevice;
use arcv::util::prop::{check, require, Gen};
use arcv::util::ring::RingBuffer;

fn gen_window(g: &mut Gen) -> Vec<f64> {
    let w = g.usize(2, 24);
    let base = g.f64(0.05, 64.0);
    (0..w).map(|_| (base * g.f64(0.5, 1.5)).max(1e-3)).collect()
}

fn gen_state(g: &mut Gen) -> PodState {
    PodState {
        state: *g.pick(&[State::Growing, State::Dynamic, State::Stable]),
        nosig: g.usize(0, 5) as f64,
        persist: g.usize(0, 5) as f64,
        gmax: g.f64(0.0, 100.0),
        rec: g.f64(0.01, 120.0),
    }
}

// --------------------------------------------------------- state machine --

#[test]
fn prop_rec_always_covers_need() {
    check("rec >= usage+swap", 400, |g| {
        let win = gen_window(g);
        let swap = if g.bool(0.3) { g.f64(0.0, 4.0) } else { 0.0 };
        let mut st = gen_state(g);
        st.step(&win, swap, &ArcvParams::default());
        let need = win.last().unwrap() + swap;
        require(st.rec + 1e-9 >= need, "rec must cover live need")
    });
}

#[test]
fn prop_gmax_is_monotone_nondecreasing() {
    check("gmax monotone", 400, |g| {
        let mut st = gen_state(g);
        let before = st.gmax;
        st.step(&gen_window(g), 0.0, &ArcvParams::default());
        require(st.gmax + 1e-12 >= before, "gmax never decreases")
    });
}

#[test]
fn prop_dynamic_never_transitions_to_growing() {
    check("no dynamic->growing", 400, |g| {
        let mut st = gen_state(g);
        st.state = State::Dynamic;
        st.step(&gen_window(g), 0.0, &ArcvParams::default());
        require(st.state != State::Growing, "§3.3 forbids Dynamic→Growing")
    });
}

#[test]
fn prop_counters_stay_bounded_and_nonnegative() {
    check("counters sane", 400, |g| {
        let mut st = gen_state(g);
        let prev_nosig = st.nosig;
        st.step(&gen_window(g), 0.0, &ArcvParams::default());
        require(st.nosig >= 0.0 && st.persist >= 0.0, "non-negative")?;
        require(
            st.nosig <= prev_nosig + 1.0,
            "nosig grows by at most one per tick",
        )
    });
}

#[test]
fn prop_dynamic_rec_never_below_global_max() {
    check("dynamic floor", 400, |g| {
        let win = gen_window(g);
        let mut st = gen_state(g);
        st.state = State::Dynamic;
        st.step(&win, 0.0, &ArcvParams::default());
        if st.state == State::Dynamic {
            require(st.rec + 1e-9 >= st.gmax, "decrease limited to global max")
        } else {
            Ok(())
        }
    });
}

#[test]
fn prop_step_is_deterministic() {
    check("step deterministic", 200, |g| {
        let win = gen_window(g);
        let swap = g.f64(0.0, 2.0);
        let st0 = gen_state(g);
        let mut a = st0;
        let mut b = st0;
        let sa = a.step(&win, swap, &ArcvParams::default());
        let sb = b.step(&win, swap, &ArcvParams::default());
        require(sa == sb && a == b, "same inputs, same outputs")
    });
}

// --------------------------------------------------------------- signals --

#[test]
fn prop_signal_scale_invariant() {
    check("signal scale invariance", 300, |g| {
        let win = gen_window(g);
        let k = g.f64(0.01, 100.0);
        let scaled: Vec<f64> = win.iter().map(|x| x * k).collect();
        let (a, _) = detect(&win, 0.02);
        let (b, _) = detect(&scaled, 0.02);
        require(a == b, "relative bands are scale invariant")
    });
}

#[test]
fn prop_big_drop_forces_signal_ii() {
    check("drop forces II", 300, |g| {
        let mut win = gen_window(g);
        let i = g.usize(1, win.len() - 1);
        win[i] = win[i - 1] * 0.5; // 50% drop >> 2% band
        let (sig, _) = detect(&win, 0.02);
        require(sig == Signal::II, "unsorted window is signal II")
    });
}

#[test]
fn prop_wider_band_never_creates_signals() {
    check("band monotonicity", 300, |g| {
        let win = gen_window(g);
        let (tight, _) = detect(&win, 0.02);
        let (loose, _) = detect(&win, 0.20);
        // a looser band can only demote signals toward None
        require(
            !(tight == Signal::None && loose != Signal::None),
            "loosening the band cannot create a signal",
        )
    });
}

// --------------------------------------------------------------- kubelet --

struct RandWalk {
    vals: Vec<f64>,
}

impl MemoryProcess for RandWalk {
    fn usage_gb(&self, t: f64) -> f64 {
        self.vals[(t as usize).min(self.vals.len() - 1)]
    }
    fn duration_secs(&self) -> f64 {
        self.vals.len() as f64
    }
    fn name(&self) -> &str {
        "randwalk"
    }
}

#[test]
fn prop_rss_never_exceeds_effective_limit() {
    check("rss <= limit", 60, |g| {
        let n = g.usize(50, 200);
        let mut v = g.f64(0.5, 4.0);
        let vals: Vec<f64> = (0..n)
            .map(|_| {
                v = (v * g.f64(0.8, 1.25)).clamp(0.05, 16.0);
                v
            })
            .collect();
        let mut c = Cluster::single_node(Node::new("w", 64.0, SwapDevice::hdd(64.0)));
        let limit = g.f64(1.0, 8.0);
        let id = c.create_pod("p", ResourceSpec::memory_exact(limit), Box::new(RandWalk { vals }));
        for _ in 0..n * 3 {
            c.step();
            // random in-place patches while running
            if g.bool(0.05) && c.pod(id).is_running() {
                let rv = c.pod(id).resource_version;
                c.patch_pod_memory(id, g.f64(0.5, 12.0));
                require(
                    c.pod(id).resource_version == rv + 1,
                    "resourceVersion bumps on every patch",
                )?;
            }
            let p = c.pod(id);
            require(
                p.usage.rss_gb <= p.effective_limit_gb + 1e-9,
                "rss within enforced limit",
            )?;
            if p.is_done() {
                break;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_swap_accounting_conserved() {
    check("swap conservation", 40, |g| {
        let n = g.usize(50, 150);
        let vals: Vec<f64> = (0..n).map(|_| g.f64(0.5, 6.0)).collect();
        let mut c = Cluster::single_node(Node::new("w", 64.0, SwapDevice::hdd(32.0)));
        let id = c.create_pod(
            "p",
            ResourceSpec::memory_exact(g.f64(1.0, 3.0)),
            Box::new(RandWalk { vals }),
        );
        for _ in 0..n * 4 {
            c.step();
            let pod_swap: f64 = c.pod(id).usage.swap_gb;
            let dev_used = c.nodes[0].swap.used_gb;
            require(
                (pod_swap - dev_used).abs() < 1e-6,
                "single pod's swap must equal device residency",
            )?;
            if c.pod(id).is_done() {
                break;
            }
        }
        Ok(())
    });
}

// ------------------------------------------------------------- scheduler --

#[test]
fn prop_scheduler_never_overcommits_requests() {
    check("scheduler fit", 200, |g| {
        let n_nodes = g.usize(1, 5);
        let mut nodes: Vec<Node> = (0..n_nodes)
            .map(|i| Node::new(&format!("w{i}"), g.f64(32.0, 256.0), SwapDevice::disabled()))
            .collect();
        let sched = Scheduler::new(if g.bool(0.5) {
            Strategy::BestFit
        } else {
            Strategy::WorstFit
        });
        for pod in 0..g.usize(1, 30) {
            let req = g.f64(1.0, 80.0);
            if let Some(i) = sched.place(&nodes, req) {
                require(nodes[i].fits(req), "placed only where it fits")?;
                nodes[i].bind(pod, req);
            }
            for nd in &nodes {
                require(
                    nd.reserved_gb <= nd.capacity_gb + 1e-9,
                    "reservations within capacity",
                )?;
            }
        }
        Ok(())
    });
}

// -------------------------------------------------------------- ring/vpa --

#[test]
fn prop_ring_matches_vec_model() {
    check("ring == vec model", 300, |g| {
        let cap = g.usize(1, 16);
        let n = g.usize(0, 48);
        let mut ring = RingBuffer::new(cap);
        let mut model: Vec<f64> = Vec::new();
        for _ in 0..n {
            let x = g.f64(-10.0, 10.0);
            ring.push(x);
            model.push(x);
            if model.len() > cap {
                model.remove(0);
            }
        }
        require(ring.to_vec() == model, "ring equals sliding vec")?;
        require(ring.last() == model.last().copied(), "last matches")
    });
}

#[test]
fn prop_vpa_staircase_is_geometric() {
    check("vpa staircase", 200, |g| {
        let init = g.f64(0.1, 10.0);
        let k = g.usize(1, 8);
        let mut p = VpaSimPolicy::new(init);
        for _ in 0..k {
            // OOM exactly at the recommendation (the growth-app case)
            let rec = p.recommendation_gb().unwrap();
            p.on_oom(0, rec);
        }
        let expect = init * 1.2f64.powi(k as i32);
        let got = p.recommendation_gb().unwrap();
        require((got - expect).abs() / expect < 1e-9, "rec = init·1.2^k")
    });
}
