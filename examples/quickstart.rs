//! Quickstart: run one HPC application under ARC-V on the cluster
//! simulator and see the memory savings.
//!
//!   cargo run --release --example quickstart

use arcv::coordinator::controller::{run_to_completion, Controller};
use arcv::policy::arcv::{ArcvParams, ArcvPolicy};
use arcv::simkube::{ApiClient, Cluster, Node, ResourceSpec};
use arcv::workloads::{build, AppId};

fn main() {
    // 1. A paper-style worker node: 256 GB RAM, HDD-backed swap enabled.
    let mut cluster = Cluster::single_node(Node::cloudlab("worker-0"));

    // 2. A containerized HPC workload — Kripke, calibrated to Table 1
    //    (650 s, 5.5 GB peak). Initial allocation: 120 % of its max.
    //    The pod is created through the typed API client, so admission
    //    validates the spec exactly as kube-apiserver would.
    let app = build(AppId::Kripke, 42);
    let initial_gb = app.max_gb * 1.2;
    let pod = ApiClient::new()
        .create_pod(
            &mut cluster,
            "kripke-0",
            ResourceSpec::memory_exact(initial_gb),
            Box::new(app),
        )
        .expect("pod admitted");

    // 3. The ARC-V controller manages the pod: it scrapes the 5 s metrics,
    //    classifies the consumption pattern (Growing/Dynamic/Stable), and
    //    issues in-place resize patches.
    let mut controller = Controller::new();
    controller.manage(pod, Box::new(ArcvPolicy::new(initial_gb, ArcvParams::default())));

    run_to_completion(&mut cluster, &mut controller, 100_000);

    // 4. Results (the controller's audit log shows each applied resize).
    let applied = controller
        .actions()
        .iter()
        .filter(|a| a.outcome == arcv::simkube::Outcome::Applied)
        .count();
    println!("API actions applied by the controller: {applied}");
    let p = cluster.pod(pod);
    let static_fp = initial_gb * p.wall_running_secs as f64;
    println!("pod finished: {:?} in {} s", p.phase, p.wall_running_secs);
    println!("OOM kills: {}", cluster.events.count_ooms(pod));
    println!("resizes applied: {}", cluster.events.resize_latencies(pod).len());
    println!("provisioned: {:>10.1} GB·s (ARC-V)", p.provisioned_gb_secs);
    println!("             {:>10.1} GB·s (static {initial_gb:.1} GB allocation)", static_fp);
    println!("actually used {:>9.1} GB·s", p.used_gb_secs);
    println!(
        "memory saved: {:.1}% of the static reservation",
        (1.0 - p.provisioned_gb_secs / static_fp) * 100.0
    );
}
