//! §5 "Use cases": ARC-V's savings enable multi-tenancy. Six Table 1
//! applications co-locate on ONE paper-spec 256 GB node; the fleet
//! controller right-sizes each pod, freeing allocatable memory that static
//! reservations would hold for the whole run.
//!
//!   cargo run --release --example multi_tenant

use arcv::coordinator::controller::Tick;
use arcv::coordinator::fleet::FleetController;
use arcv::policy::arcv::{ArcvParams, NativeFleet};
use arcv::simkube::{ApiClient, Cluster, Node, ResourceSpec};
use arcv::util::plot::line;
use arcv::workloads::{build, AppId};

fn main() {
    let apps = [
        AppId::Minife,    // 63.7 GB peak
        AppId::Bfs,       // 48.4 GB peak
        AppId::Kripke,    // 5.5 GB
        AppId::Cm1,       // 415 MB
        AppId::Lulesh,    // 696 MB
        AppId::Lammps,    // 23.7 MB
    ];
    let mut cluster = Cluster::single_node(Node::cloudlab("worker-0"));
    let params = ArcvParams::default();
    let mut ctl = FleetController::from_backend(Box::new(NativeFleet::new(64, params.window)), params);

    let mut api = ApiClient::new(); // the tenant-facing admission surface
    let mut static_sum = 0.0;
    let mut ids = Vec::new();
    for (i, app) in apps.iter().enumerate() {
        let model = build(*app, 42 + i as u64);
        let init = model.max_gb * 1.2;
        static_sum += init;
        let id = api
            .create_pod(&mut cluster, app.name(), ResourceSpec::memory_exact(init), Box::new(model))
            .expect("tenant pod admitted");
        ctl.manage(id, init);
        ids.push((id, *app));
    }
    println!(
        "co-locating {} pods on one 256 GB node (static reservations would hold {:.1} GB)",
        apps.len(),
        static_sum
    );

    let mut reserved_series = Vec::new();
    while !cluster.all_done() && cluster.now < 60_000 {
        cluster.step();
        ctl.tick(&mut cluster);
        if cluster.now % 5 == 0 {
            reserved_series.push(cluster.nodes[0].reserved_gb);
        }
    }

    println!();
    for (id, app) in &ids {
        let p = cluster.pod(*id);
        println!(
            "  {:<10} {:?} in {:>5} s  ooms={} final-limit={:>8.3} GB",
            app.name(),
            p.phase,
            p.wall_running_secs,
            cluster.events.count_ooms(*id),
            p.effective_limit_gb,
        );
    }

    let avg_reserved = reserved_series.iter().sum::<f64>() / reserved_series.len() as f64;
    let min_reserved = reserved_series.iter().cloned().fold(f64::MAX, f64::min);
    println!();
    print!(
        "{}",
        line(
            "node reserved memory over time (GB) — ARC-V frees headroom as pods shrink/finish",
            &reserved_series,
            96,
            12,
        )
    );
    println!(
        "\nstatic reservations: {static_sum:.1} GB for the whole run\n\
         ARC-V reservations:  avg {avg_reserved:.1} GB, min {min_reserved:.1} GB\n\
         freed headroom lets the scheduler admit more work (the paper's Kripke+CM1+LULESH+LAMMPS case)"
    );
}
