//! The §5 deployment shape: the ARC-V controller runs on "another node" —
//! here a separate thread talking to the cluster only through channels
//! (metrics in, patches out) — while the kubelet's Prometheus endpoint is
//! scraped periodically, exactly what a Grafana/Prometheus stack would see.
//!
//!   cargo run --release --example live_controller

use arcv::coordinator::remote::run_remote;
use arcv::policy::arcv::{ArcvParams, ArcvPolicy};
use arcv::policy::VerticalPolicy;
use arcv::simkube::{ApiClient, Cluster, Node, PodId, ResourceSpec};
use arcv::workloads::{build, AppId};
use std::collections::BTreeMap;

fn main() {
    let mut cluster = Cluster::single_node(Node::cloudlab("worker-0"));
    let mut api = ApiClient::new();
    let mut policies: Vec<(PodId, Box<dyn VerticalPolicy>)> = Vec::new();
    let mut names = BTreeMap::new();

    for (i, app) in [AppId::Kripke, AppId::Lulesh, AppId::Cm1].iter().enumerate() {
        let model = build(*app, 7 + i as u64);
        let init = model.max_gb * 1.2;
        let id = api
            .create_pod(
                &mut cluster,
                &format!("{}-0", app.name()),
                ResourceSpec::memory_exact(init),
                Box::new(model),
            )
            .expect("pod admitted");
        names.insert(id, format!("{}-0", app.name()));
        policies.push((id, Box::new(ArcvPolicy::new(init, ArcvParams::default()))));
    }

    println!("controller running on its own thread; scraping kubelet every 120 s:\n");

    // Drive in slices so we can scrape the Prometheus endpoint "live".
    let pods: Vec<PodId> = names.keys().copied().collect();
    let mut remaining = policies;
    let mut offset = 0u64;
    loop {
        // run_remote consumes policies; run one 120s slice at a time by
        // keeping the controller alive across the whole run instead:
        let ticks = run_remote(&mut cluster, std::mem::take(&mut remaining), 120);
        offset += ticks;
        println!("--- t={offset}s ---");
        print!("{}", cluster.metrics.prometheus_text(&names));
        for &id in &pods {
            let p = cluster.pod(id);
            println!(
                "  {:<10} phase={:?} limit={:.3} GB",
                names[&id], p.phase, p.effective_limit_gb
            );
        }
        println!();
        if cluster.all_done() || offset > 20_000 {
            break;
        }
        // re-arm fresh policies with the current limits (state persists in
        // the cluster; the controller is stateless across slices here for
        // demo simplicity)
        remaining = pods
            .iter()
            .map(|&id| {
                let lim = cluster.pod(id).effective_limit_gb;
                (
                    id,
                    Box::new(ArcvPolicy::new(lim, ArcvParams::default()))
                        as Box<dyn VerticalPolicy>,
                )
            })
            .collect();
    }
    println!("all pods completed at t={offset}s");
}
