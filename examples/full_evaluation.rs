//! **End-to-end driver** (deliverable (b)/EXPERIMENTS.md): the paper's full
//! §5 evaluation on a real small workload — all nine Table 1 applications,
//! each run under (a) the simulated Kubernetes VPA and (b) ARC-V with the
//! **AOT-compiled XLA decision artifact on the hot path** (the deployed
//! three-layer configuration: Rust coordinator → PJRT → the JAX/Pallas
//! decision step lowered at build time).
//!
//!   make artifacts && cargo run --release --example full_evaluation
//!
//! Prints the Fig 4 ratio table and writes bench_out/full_evaluation.csv.

use arcv::harness::{ratio_row, ratio_table, ratios_csv, run, run_line, ExperimentConfig, PolicyKind};
use arcv::policy::arcv::ArcvParams;
use arcv::runtime::{Engine, Manifest, XlaFleet};
use arcv::workloads::TABLE1;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::discover()?;
    let engine = Engine::cpu()?;
    println!(
        "PJRT platform: {} | artifacts: {}",
        engine.platform(),
        manifest.dir.display()
    );
    let params = ArcvParams::default();

    let mut rows = Vec::new();
    for row in &TABLE1 {
        // Baseline: the paper's §4.1 VPA simulator (no swap; OOM → +20%).
        let vpa = run(&ExperimentConfig::vpa_env(row.app), PolicyKind::VpaSim);
        println!("{}", run_line(&vpa));

        // ARC-V with the XLA artifact making every decision.
        let fleet = XlaFleet::from_manifest(&engine, &manifest, 64)?;
        let arcv = run(
            &ExperimentConfig::arcv_env(row.app),
            PolicyKind::ArcvFleet(params, Box::new(fleet)),
        );
        println!("{}", run_line(&arcv));

        assert!(arcv.completed, "{}: ARC-V run must complete", row.app);
        assert_eq!(arcv.oom_count, 0, "{}: ARC-V eliminates OOMs", row.app);
        rows.push(ratio_row(&vpa, &arcv, row.exec_secs));
    }

    println!("\n=== Fig 4 (left) — VPA/ARC-V ratios, XLA decision path ===\n");
    println!("{}", ratio_table(&rows));
    std::fs::create_dir_all("bench_out").ok();
    ratios_csv(&rows).save("bench_out/full_evaluation.csv")?;
    println!("wrote bench_out/full_evaluation.csv");

    // headline sanity: memory saved overall, zero ARC-V OOMs, VPA pays
    // restarts on growth apps
    let total_fp_ratio: f64 =
        rows.iter().map(|r| r.footprint_ratio).sum::<f64>() / rows.len() as f64;
    println!("\nmean footprint ratio (VPA/ARC-V): {total_fp_ratio:.2}x");
    assert!(total_fp_ratio > 1.5, "ARC-V must save memory on average");
    println!("full evaluation OK");
    Ok(())
}
