//! §1's motivation, quantified: MPI jobs are gangs — one rank's OOM kills
//! the whole application. Under the VPA baseline a single under-provisioned
//! rank repeatedly restarts all ranks from scratch; ARC-V (swap + top-down
//! limits) never OOMs, so the gang never loses progress.
//!
//!   cargo run --release --example mpi_gang

use arcv::coordinator::controller::run_to_completion;
use arcv::coordinator::gang::GangSupervisor;
use arcv::policy::arcv::{ArcvParams, ArcvPolicy};
use arcv::policy::vpa::VpaSimPolicy;
use arcv::policy::VerticalPolicy;
use arcv::simkube::{ApiClient, Cluster, Node, PodId, ResourceSpec, SwapDevice};
use arcv::workloads::{build, AppId};

const RANKS: usize = 4;

fn build_gang(
    cluster: &mut Cluster,
    initial_frac: f64,
) -> Vec<(PodId, f64)> {
    // 4 sputniPIC ranks with slightly skewed memory (rank 0 holds extra
    // field data — the usual MPI imbalance), admitted through the API
    let mut api = ApiClient::new();
    (0..RANKS)
        .map(|rank| {
            let model = build(AppId::Sputnipic, 100 + rank as u64);
            let skew = 1.0 + 0.15 * (rank == 0) as u8 as f64;
            let init = model.max_gb * initial_frac * skew;
            let id = api
                .create_pod(
                    cluster,
                    &format!("sputnipic-rank{rank}"),
                    ResourceSpec::memory_exact(init),
                    Box::new(model),
                )
                .expect("rank admitted");
            (id, init)
        })
        .collect()
}

fn main() {
    println!("=== {RANKS}-rank MPI gang (sputniPIC): VPA vs ARC-V ===\n");

    // --- VPA: no swap, 20% initial → rank OOMs amplify to gang restarts
    let mut c = Cluster::single_node(Node::new("w0", 256.0, SwapDevice::disabled()));
    let members = build_gang(&mut c, 0.2);
    let mut sup = GangSupervisor::new();
    sup.supervise(
        "job",
        members
            .iter()
            .map(|&(id, init)| {
                (id, Box::new(VpaSimPolicy::new(init)) as Box<dyn VerticalPolicy>)
            })
            .collect(),
    );
    let ticks = run_to_completion(&mut c, &mut sup, 200_000);
    let g = sup.gang("job").unwrap();
    let rank_restarts: u32 = members.iter().map(|&(id, _)| c.pod(id).restarts).sum();
    println!(
        "VPA   : wall {:>6}s (nominal 210s)  gang restarts {:>2}  rank restarts {:>3}  done={}",
        ticks,
        g.gang_restarts,
        rank_restarts,
        sup.gang_done(&c, "job"),
    );

    // --- ARC-V: swap on, 120% initial → zero OOMs, zero lost progress
    let mut c = Cluster::single_node(Node::new("w0", 256.0, SwapDevice::hdd(128.0)));
    let members = build_gang(&mut c, 1.2);
    let mut sup = GangSupervisor::new();
    sup.supervise(
        "job",
        members
            .iter()
            .map(|&(id, init)| {
                (
                    id,
                    Box::new(ArcvPolicy::new(init, ArcvParams::default()))
                        as Box<dyn VerticalPolicy>,
                )
            })
            .collect(),
    );
    let ticks = run_to_completion(&mut c, &mut sup, 200_000);
    let g = sup.gang("job").unwrap();
    println!(
        "ARC-V : wall {:>6}s (nominal 210s)  gang restarts {:>2}  rank restarts {:>3}  done={}",
        ticks,
        g.gang_restarts,
        members.iter().map(|&(id, _)| c.pod(id).restarts).sum::<u32>(),
        sup.gang_done(&c, "job"),
    );
    println!(
        "\nthe §1 amplification: under VPA every rank's OOM restarts ALL {RANKS} ranks \
         from scratch;\nARC-V's OOM-free operation keeps the gang's progress intact."
    );
}
