//! §3.2/§3.3 swap behaviour under stress: MiniFE's end-of-run spike with
//! the provisioned limit *below* the spike. Swap absorbs what would have
//! been an OOM kill; device bandwidth sets the price; the §3.2 downsize
//! sync-delay semantics are visible in the resize latencies.
//!
//!   cargo run --release --example swap_stress

use arcv::harness::{run, run_line, ExperimentConfig, PolicyKind, SwapKind};
use arcv::policy::arcv::ArcvParams;
use arcv::simkube::{ApiClient, Cluster, EventKind, Node, ResourceSpec, SwapDevice};
use arcv::util::plot::multi_line;
use arcv::workloads::{build, AppId};

fn main() {
    println!("=== MiniFE end spike vs swap device class ===\n");
    for (label, swap) in [
        ("hdd 0.1 GB/s", SwapKind::Hdd(128.0)),
        ("ssd 1.0 GB/s", SwapKind::Ssd(128.0)),
        ("no swap     ", SwapKind::Disabled),
    ] {
        let mut cfg = ExperimentConfig::arcv_env(AppId::Minife);
        cfg.initial_frac = 0.9; // 57.3 GB limit < 63.7 GB spike
        cfg.swap = swap;
        cfg.budget_mult = 30.0;
        let r = run(&cfg, PolicyKind::ArcvNative(ArcvParams::default()));
        println!("  [{label}] {}", run_line(&r));
        let max_swap = r.swap_series.iter().map(|&(_, s)| s).fold(0.0_f64, f64::max);
        println!("             peak swap residency: {max_swap:.2} GB");
    }

    // Zoom in on the HDD case: usage vs limit vs swap at the end of run.
    println!("\n=== anatomy of the spike (HDD swap) ===\n");
    let mut cfg = ExperimentConfig::arcv_env(AppId::Minife);
    cfg.initial_frac = 0.9;
    cfg.budget_mult = 30.0;
    let r = run(&cfg, PolicyKind::ArcvNative(ArcvParams::default()));
    let tail = r.usage_series.len().saturating_sub(30);
    let usage: Vec<f64> = r.usage_series[tail..].iter().map(|&(_, v)| v).collect();
    let limit: Vec<f64> = r.limit_series[tail..].iter().map(|&(_, v)| v).collect();
    let swap: Vec<f64> = r.swap_series[tail..].iter().map(|&(_, v)| v).collect();
    print!(
        "{}",
        multi_line(
            "last ~150s: usage / effective limit / swap (GB)",
            &[("usage", &usage), ("limit", &limit), ("swap", &swap)],
            96,
            14,
        )
    );

    // §3.2: a downsize below the resident set is 'significantly prolonged'.
    println!("\n=== §3.2 resize-sync semantics (direct kubelet observation) ===\n");
    let mut c = Cluster::single_node(Node::new("w0", 64.0, SwapDevice::hdd(32.0)));
    let mut api = ApiClient::new();
    let id = api
        .create_pod(
            &mut c,
            "steady",
            ResourceSpec::memory_exact(8.0),
            Box::new(build(AppId::Gromacs, 1)),
        )
        .expect("pod admitted");
    c.run_until(200, |_| false);
    // patches go through the API: above rss? 4.2 rss -> plain delay
    api.patch_pod_memory(&mut c, id, 6.0, None).expect("patch admitted");
    c.run_until(30, |c| c.pod(id).pending_resize.is_none());
    // below rss: must reclaim via swap first
    api.patch_pod_memory(&mut c, id, 2.0, None).expect("patch admitted");
    c.run_until(600, |c| c.pod(id).pending_resize.is_none());
    for lat in c.events.resize_latencies(id) {
        println!("  resize applied after {lat} s");
    }
    let swapped: f64 = c
        .events
        .iter()
        .filter_map(|e| match e.kind {
            EventKind::SwappedOut { gb } if e.pod == id => Some(gb),
            _ => None,
        })
        .sum();
    println!("  pages reclaimed to swap during downsize: {swapped:.2} GB");
    println!("\n(the second resize is the §3.2 'significantly prolonged' case)");
}
