//! Loadgen quickstart: capture a churny scenario run to a `$timestamp
//! $json`-lines trace file, parse it back, replay the captured schedule
//! through `Arrivals::Trace`, and verify the replayed watch stream is
//! bit-identical to the captured one — in every kernel mode. This is the
//! CI smoke for the trace capture/replay paths; it finishes in seconds.
//!
//!   cargo run --release --example trace_replay

use arcv::harness::SwapKind;
use arcv::loadgen::{mode_label, Trace};
use arcv::policy::arcv::ArcvParams;
use arcv::scenario::{
    outcome_line, run_scenario, run_scenario_mode, Arrivals, Fault, ScenarioPolicy, ScenarioSpec,
    WorkloadMix,
};
use arcv::simkube::KernelMode;
use arcv::workloads::AppId;

fn main() {
    // a run worth replaying: Poisson arrivals, a kill and a drain, so the
    // trace carries fault events and requeue churn, not just happy-path
    // scheduling
    let spec = ScenarioSpec::new("trace-smoke")
        .pool("w", 2, 64.0, SwapKind::Hdd(32.0))
        .arrivals(Arrivals::Poisson { rate_per_min: 6.0 })
        .jobs(12)
        .mix(WorkloadMix::uniform(&[AppId::Amr, AppId::Cm1, AppId::Sputnipic]))
        .fault(Fault::KillRandomPod { at: 150 })
        .fault(Fault::DrainNode { at: 400, node: 1 })
        .max_ticks(60_000);
    let policy = ScenarioPolicy::Arcv(ArcvParams::default());
    let seed = 7;

    let run = run_scenario(&spec, policy, seed);
    println!("captured: {}", outcome_line(&run.outcome));
    let trace = Trace::capture(&spec, &policy, seed, &run);
    let text = trace.to_lines();
    println!(
        "trace: {} jobs + {} watch records -> {} lines / {} bytes\n",
        trace.header.jobs,
        trace.header.records,
        text.lines().count(),
        text.len(),
    );

    let mut failed = false;

    // the file round-trips exactly
    let parsed = match Trace::parse(&text) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("FAIL: captured trace does not parse: {e}");
            std::process::exit(1);
        }
    };
    if parsed != trace {
        eprintln!("FAIL: parse(to_lines(trace)) is not the identity");
        failed = true;
    }

    // replay is bit-identical in every kernel mode
    let replay_spec = parsed.replay_spec(&spec).expect("replayable schedule");
    for mode in [
        KernelMode::Lockstep,
        KernelMode::EventDriven,
        KernelMode::Sharded { threads: 0 },
    ] {
        let replayed = run_scenario_mode(&replay_spec, policy, parsed.header.seed, mode);
        match parsed.verify_replay(&replayed) {
            Ok(()) => println!(
                "replay [{}]: bit-identical ({} records, outcome match: {})",
                mode_label(mode),
                replayed.cluster.events.retained_len(),
                replayed.outcome == run.outcome,
            ),
            Err(e) => {
                eprintln!("FAIL: replay [{}]: {e}", mode_label(mode));
                failed = true;
            }
        }
        if replayed.outcome != run.outcome {
            eprintln!("FAIL: replay [{}] outcome differs", mode_label(mode));
            failed = true;
        }
    }

    // tampered files fail loudly, not quietly
    if Trace::parse(&text.replace("\"version\":1", "\"version\":99")).is_ok() {
        eprintln!("FAIL: version mismatch was not rejected");
        failed = true;
    }

    if failed {
        std::process::exit(1);
    }
    println!("\ntrace paths exercised: capture, serialize, parse, replay — bit-for-bit");
}
