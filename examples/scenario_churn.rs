//! Scenario quickstart: a small heterogeneous cluster under a bursty job
//! stream with churn — a random pod kill, a node drain, and a mid-life
//! memory-leak pod — run under ARC-V and the VPA simulator. This is the
//! CI smoke for the churn paths; it finishes in seconds. (Arrivals are
//! bursty rather than Poisson so pods are deterministically running when
//! the kill and drain injectors fire — the Poisson regime is exercised by
//! the `scenario_fleet` bench and the integration tests.)
//!
//!   cargo run --release --example scenario_churn

use arcv::harness::SwapKind;
use arcv::policy::arcv::ArcvParams;
use arcv::scenario::{
    outcome_line, run_scenario, Arrivals, Fault, ScenarioPolicy, ScenarioSpec, WorkloadMix,
};
use arcv::simkube::EventKind;
use arcv::workloads::AppId;

fn main() {
    let spec = ScenarioSpec::new("churn-smoke")
        .pool("hi", 2, 64.0, SwapKind::Hdd(32.0))
        .pool("lo", 1, 32.0, SwapKind::Ssd(16.0))
        .arrivals(Arrivals::Bursty { period_secs: 60, burst: 3 })
        .jobs(10)
        .mix(WorkloadMix::uniform(&[
            AppId::Amr,
            AppId::Cm1,
            AppId::Kripke,
            AppId::Lulesh,
            AppId::Sputnipic,
        ]))
        .fault(Fault::KillRandomPod { at: 120 })
        .fault(Fault::LeakyPod {
            at: 200,
            base_gb: 2.0,
            leak_gb_per_sec: 0.01,
            lifetime_secs: 400.0,
        })
        .fault(Fault::DrainNode { at: 300, node: 2 })
        .max_ticks(60_000);

    println!(
        "churn smoke: {} nodes, {} jobs + 1 leak pod, kill@120 drain@300\n",
        spec.node_count(),
        spec.jobs
    );

    let mut failed = false;
    for policy in [ScenarioPolicy::Arcv(ArcvParams::default()), ScenarioPolicy::VpaSim] {
        let run = run_scenario(&spec, policy, 7);
        println!("{}", outcome_line(&run.outcome));
        // churn actually happened: the drain displaced pods or idled a
        // node, the kill landed, the leak pod ran
        let drained = run
            .cluster
            .events
            .iter()
            .any(|e| matches!(e.kind, EventKind::NodeDrained { .. }));
        let killed = run
            .cluster
            .events
            .iter()
            .any(|e| matches!(e.kind, EventKind::PodKilled { .. }));
        if !drained || !killed {
            eprintln!("FAIL: expected churn events (drained={drained} killed={killed})");
            failed = true;
        }
        if run.outcome.stuck_pending > 0 {
            eprintln!(
                "FAIL: {} pods stuck Pending under {}",
                run.outcome.stuck_pending,
                policy.label()
            );
            failed = true;
        }
        if run.outcome.jobs_completed != run.outcome.jobs_submitted {
            eprintln!(
                "FAIL: {}/{} jobs completed under {}",
                run.outcome.jobs_completed,
                run.outcome.jobs_submitted,
                policy.label()
            );
            failed = true;
        }
    }
    if failed {
        std::process::exit(1);
    }
    println!("\nchurn paths exercised: arrivals, requeue, drain, kill, leak — all jobs done");
}
